//! Delta-Lake-style ACID table layer over an object store.
//!
//! A table is a directory containing data files (DTPQ, written by the
//! formats layer) and a `_delta_log/` of numbered JSON commits:
//!
//! ```text
//! <root>/_delta_log/00000000000000000000.json   (protocol + metaData)
//! <root>/_delta_log/00000000000000000001.json   (add / remove / commitInfo)
//! <root>/_delta_log/00000000000000000010.checkpoint.json
//! <root>/_delta_log/_last_checkpoint
//! <root>/data/part-...dtpq
//! ```
//!
//! Commits are atomic via the object store's put-if-absent primitive:
//! whoever creates `N.json` first wins version N; losers replay the winner
//! commits since their read snapshot and **arbitrate** — disjoint file
//! sets rebase onto the new version and re-commit, overlapping writes or a
//! newer `txn` for the same app-id surface a typed [`CommitConflict`]
//! (optimistic concurrency, as in Delta Lake on S3 with a coordinating
//! commit service). Co-located writers additionally serialize on a
//! per-table in-process queue before touching the store. Snapshots replay
//! the log (from the latest checkpoint) to a version, giving time travel
//! for free.

mod action;

pub use action::{commit_from_ndjson, commit_to_ndjson, Action, AddFile, Metadata};

use crate::jsonx::{self, Json};
use crate::objectstore::{ObjectStore, ObjectStoreHandle};
use crate::Result;
use anyhow::{ensure, Context};
use std::collections::{BTreeMap, HashSet};
use std::sync::Arc;

/// Write a checkpoint every this many commits.
const CHECKPOINT_INTERVAL: u64 = 10;
/// Give up after this many optimistic-concurrency retries.
const MAX_COMMIT_RETRIES: usize = 32;
/// Default cap on conflict-aware rebase rounds per commit
/// (`DT_REBASE_MAX`; 0 disables rebasing — any lost race is a conflict).
pub const DEFAULT_REBASE_MAX: u64 = 32;
/// Default per-table in-process commit-queue depth: the number of
/// co-located writers allowed to wait for the table's local commit slot
/// before further commits are refused (`DT_COMMIT_QUEUE`; 0 disables the
/// queue entirely and writers race the object store directly).
pub const DEFAULT_COMMIT_QUEUE: u64 = 64;

/// Process-wide count of `put_if_absent` races lost during commits (each
/// loss is followed by a retry against the refreshed log position).
/// Exported through the write engine's metrics (`ingest.commit_retries`).
static COMMIT_RETRIES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
/// Process-wide count of commits that were rebased onto a newer log
/// position after classifying every intervening winner as disjoint.
static COMMIT_REBASES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
/// Process-wide count of commits that waited behind another in-process
/// writer in a per-table commit queue before touching the object store.
static COMMIT_QUEUE_WAITS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total commit conflicts retried so far, process-wide.
pub fn commit_retry_count() -> u64 {
    COMMIT_RETRIES.load(std::sync::atomic::Ordering::Relaxed)
}

/// Total commits rebased onto a newer version so far, process-wide.
pub fn commit_rebase_count() -> u64 {
    COMMIT_REBASES.load(std::sync::atomic::Ordering::Relaxed)
}

/// Total commits that queued behind a co-located writer so far.
pub fn commit_queue_wait_count() -> u64 {
    COMMIT_QUEUE_WAITS.load(std::sync::atomic::Ordering::Relaxed)
}

fn rebase_max() -> u64 {
    crate::util::env_u64("DT_REBASE_MAX", DEFAULT_REBASE_MAX)
}

fn commit_queue_depth() -> u64 {
    crate::util::env_u64("DT_COMMIT_QUEUE", DEFAULT_COMMIT_QUEUE)
}

/// Typed commit-arbitration failure: the commit lost its optimistic race
/// and the winner(s) could **not** be classified as disjoint — rebasing
/// would overwrite their work (or the local commit queue refused entry).
/// Callers must re-plan against a fresh snapshot; downcast through
/// `anyhow` with `err.downcast_ref::<CommitConflict>()`.
#[derive(Debug, Clone)]
pub struct CommitConflict {
    /// Table root the commit targeted.
    pub table: String,
    /// Version of the conflicting winner commit, when one was identified.
    pub version: Option<u64>,
    /// Human-readable classification of why the commit cannot be rebased.
    pub reason: String,
}

impl std::fmt::Display for CommitConflict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "commit conflict on {}", self.table)?;
        if let Some(v) = self.version {
            write!(f, " at version {v}")?;
        }
        write!(f, ": {}", self.reason)
    }
}

impl std::error::Error for CommitConflict {}

/// One table's in-process commit slot: a mutex-and-condvar pair with a
/// bounded waiter count, so co-located writers serialize locally instead
/// of burning object-store round-trips racing each other.
struct TableQueue {
    busy: std::sync::Mutex<bool>,
    cv: std::sync::Condvar,
    waiters: std::sync::atomic::AtomicU64,
}

/// Releases the table's commit slot on drop.
struct QueueGuard {
    q: Arc<TableQueue>,
}

impl Drop for QueueGuard {
    fn drop(&mut self) {
        *self.q.busy.lock().unwrap() = false;
        self.q.cv.notify_one();
    }
}

impl TableQueue {
    fn acquire(self: &Arc<Self>, table: &str, max_waiters: u64) -> Result<QueueGuard> {
        use std::sync::atomic::Ordering;
        let mut busy = self.busy.lock().unwrap();
        if *busy {
            if self.waiters.fetch_add(1, Ordering::SeqCst) >= max_waiters {
                self.waiters.fetch_sub(1, Ordering::SeqCst);
                return Err(anyhow::Error::new(CommitConflict {
                    table: table.to_string(),
                    version: None,
                    reason: format!("local commit queue full ({max_waiters} waiters)"),
                }));
            }
            COMMIT_QUEUE_WAITS.fetch_add(1, Ordering::Relaxed);
            while *busy {
                busy = self.cv.wait(busy).unwrap();
            }
            self.waiters.fetch_sub(1, Ordering::SeqCst);
        }
        *busy = true;
        Ok(QueueGuard { q: Arc::clone(self) })
    }
}

/// Per-table commit queues, keyed like the snapshot cache by
/// `(store instance, table root)` so distinct stores never share a slot.
static COMMIT_QUEUES: once_cell::sync::Lazy<
    std::sync::Mutex<std::collections::HashMap<(u64, String), Arc<TableQueue>>>,
> = once_cell::sync::Lazy::new(|| std::sync::Mutex::new(std::collections::HashMap::new()));

/// Milliseconds since the Unix epoch, **strictly monotonic within the
/// process**: two calls never return the same value even inside one
/// millisecond. Commit/Add timestamps therefore uniquely distinguish
/// successive rewrites of the same part path, which the read engine's
/// footer cache keys on (path, size, timestamp) — without monotonicity, a
/// same-millisecond same-size rewrite could be served a stale footer.
pub fn now_ms() -> i64 {
    use std::sync::atomic::{AtomicI64, Ordering};
    static LAST: AtomicI64 = AtomicI64::new(0);
    let wall = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as i64)
        .unwrap_or(0);
    LAST.fetch_max(wall, Ordering::Relaxed);
    // Claim a unique tick at or after the wall clock.
    LAST.fetch_add(1, Ordering::Relaxed)
}

/// A materialized view of the table at one version.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Version this snapshot reflects.
    pub version: u64,
    /// Table metadata (latest metaData action at or before `version`).
    pub metadata: Metadata,
    /// Live data files by path.
    pub files: BTreeMap<String, AddFile>,
    /// Application transactions: highest `txn` version recorded per
    /// `app_id` at or before `version` (the protocol's idempotence table).
    pub txns: BTreeMap<String, u64>,
}

impl Snapshot {
    /// Highest `txn` version recorded for `app_id`, if any.
    pub fn txn_version(&self, app_id: &str) -> Option<u64> {
        self.txns.get(app_id).copied()
    }

    /// Live files, sorted by path.
    pub fn files(&self) -> impl Iterator<Item = &AddFile> {
        self.files.values()
    }

    /// Live files belonging to a tensor id.
    pub fn files_for_tensor(&self, tensor_id: &str) -> Vec<&AddFile> {
        self.files.values().filter(|f| f.tensor_id == tensor_id).collect()
    }

    /// Total data bytes referenced by the snapshot.
    pub fn total_bytes(&self) -> u64 {
        self.files.values().map(|f| f.size).sum()
    }

    /// Total logical rows referenced by the snapshot.
    pub fn total_rows(&self) -> u64 {
        self.files.values().map(|f| f.rows).sum()
    }
}

/// A Delta-style table handle.
#[derive(Clone)]
pub struct DeltaTable {
    store: ObjectStoreHandle,
    root: String,
}

impl std::fmt::Debug for DeltaTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeltaTable").field("root", &self.root).finish()
    }
}

impl DeltaTable {
    /// Create a new table at `root` (commit 0: protocol + metadata).
    pub fn create(store: ObjectStoreHandle, root: &str) -> Result<Self> {
        let t = Self { store, root: root.trim_matches('/').to_string() };
        let meta = Metadata {
            id: format!("tbl-{:016x}", crate::util::SplitMix64::new(now_ms() as u64).next_u64()),
            name: t.root.clone(),
            schema: Json::Null,
            created: now_ms(),
        };
        let actions = vec![
            Action::Protocol { min_reader: 1, min_writer: 1 },
            Action::Metadata(meta),
            Action::CommitInfo { operation: "CREATE TABLE".into(), timestamp: now_ms() },
        ];
        let body = commit_to_ndjson(&actions);
        let ok = t.store.put_if_absent(&t.commit_key(0), body.as_bytes())?;
        ensure!(ok, "table already exists at {root}");
        t.journal("CREATE TABLE", Some(0), 0, 0, 0, 0, 0.0, "ok");
        Ok(t)
    }

    /// Open an existing table.
    pub fn open(store: ObjectStoreHandle, root: &str) -> Result<Self> {
        let t = Self { store, root: root.trim_matches('/').to_string() };
        ensure!(
            t.store.head(&t.commit_key(0))?.is_some(),
            "no delta table at {root} (missing commit 0)"
        );
        Ok(t)
    }

    /// Create if absent, else open.
    pub fn create_or_open(store: ObjectStoreHandle, root: &str) -> Result<Self> {
        let t = Self { store: store.clone(), root: root.trim_matches('/').to_string() };
        if t.store.head(&t.commit_key(0))?.is_some() {
            Self::open(store, root)
        } else {
            Self::create(store, root)
        }
    }

    /// Table root prefix.
    pub fn root(&self) -> &str {
        &self.root
    }

    /// Underlying object store handle.
    pub fn store(&self) -> &ObjectStoreHandle {
        &self.store
    }

    /// A handle to the same table whose store I/O (and commit-retry
    /// events) is attributed to `span` — how a traced operation threads
    /// its context through the engines without thread-locals. Cache
    /// instance id and stats are shared with the original.
    pub fn with_span(&self, span: &crate::telemetry::Span) -> Self {
        Self { store: self.store.with_span(span), root: self.root.clone() }
    }

    /// Key for a data object under this table.
    pub fn data_key(&self, rel: &str) -> String {
        format!("{}/{}", self.root, rel)
    }

    pub(crate) fn log_prefix(&self) -> String {
        format!("{}/_delta_log/", self.root)
    }

    pub(crate) fn commit_key(&self, version: u64) -> String {
        format!("{}{:020}.json", self.log_prefix(), version)
    }

    pub(crate) fn checkpoint_key(&self, version: u64) -> String {
        format!("{}{:020}.checkpoint.json", self.log_prefix(), version)
    }

    fn last_checkpoint_key(&self) -> String {
        format!("{}_last_checkpoint", self.log_prefix())
    }

    /// Version of the newest checkpoint per the `_last_checkpoint` hint
    /// (`None` when no checkpoint was written yet). One HEAD + one GET —
    /// the health probe's "log length since checkpoint" gauge reads this.
    pub fn last_checkpoint_version(&self) -> Result<Option<u64>> {
        if self.store.head(&self.last_checkpoint_key())?.is_none() {
            return Ok(None);
        }
        let body = self.store.get(&self.last_checkpoint_key())?;
        Ok(jsonx::parse(std::str::from_utf8(&body).unwrap_or(""))
            .ok()
            .and_then(|j| j.get("version").and_then(Json::as_u64)))
    }

    /// Latest committed version.
    pub fn latest_version(&self) -> Result<u64> {
        // Start listing from the last checkpoint hint to avoid scanning the
        // whole log prefix on long-lived tables.
        let keys = self.store.list(&self.log_prefix())?;
        let mut latest = None;
        for k in keys {
            if let Some(v) = parse_commit_version(&k) {
                latest = Some(latest.map_or(v, |l: u64| l.max(v)));
            }
        }
        latest.with_context(|| format!("no commits found under {}", self.log_prefix()))
    }

    /// Commit `actions` with optimistic concurrency. Returns the version.
    ///
    /// Equivalent to [`DeltaTable::commit_from`] with the read snapshot
    /// taken at entry — the right call when the actions were planned
    /// against the table's current state (plain writes). Callers that
    /// planned against an older snapshot (index builds, folds, upkeep)
    /// must pass that snapshot's version to `commit_from` so arbitration
    /// replays everything that landed since the plan was made.
    pub fn commit(&self, actions: Vec<Action>) -> Result<u64> {
        let read_version = self.latest_version()?;
        self.commit_from(actions, read_version)
    }

    /// Commit `actions` planned against snapshot `read_version`, with
    /// conflict-aware arbitration. Returns the landed version.
    ///
    /// Pipeline: (1) co-located writers serialize on a per-table
    /// in-process queue (`DT_COMMIT_QUEUE` waiters max) so only one local
    /// writer races the object store at a time; (2) every winner commit
    /// since `read_version` is replayed and classified **before** each
    /// `put_if_absent` attempt — disjoint file sets rebase our actions
    /// onto the new version (counted, capped by `DT_REBASE_MAX`), while an
    /// overlapping add/remove path or a `txn` action for one of our
    /// app-ids at a version `>=` ours surfaces a typed [`CommitConflict`]
    /// (the caller's plan is stale and must be re-made, as Delta does for
    /// conflicting OPTIMIZE); (3) a lost `put_if_absent` race refreshes
    /// the log position and jumps **past every commit that landed
    /// meanwhile**, instead of stepping one version at a time — a burst of
    /// concurrent winners would otherwise exhaust the retry budget.
    pub fn commit_from(&self, actions: Vec<Action>, read_version: u64) -> Result<u64> {
        let started = std::time::Instant::now();
        let op = actions
            .iter()
            .rev()
            .find_map(|a| match a {
                Action::CommitInfo { operation, .. } => Some(operation.clone()),
                _ => None,
            })
            .unwrap_or_else(|| "COMMIT".to_string());
        let adds = actions.iter().filter(|a| matches!(a, Action::Add(_))).count();
        let add_bytes: u64 = actions
            .iter()
            .filter_map(|a| match a {
                Action::Add(f) => Some(f.size),
                _ => None,
            })
            .sum();
        // One journal entry per outcome path, so failed commits are as
        // visible post-hoc as landed ones.
        let journal = |version: Option<u64>, retries: u64, outcome: &str| {
            self.journal(
                &op,
                version,
                adds,
                actions.iter().filter(|a| matches!(a, Action::Remove { .. })).count(),
                add_bytes,
                retries,
                started.elapsed().as_secs_f64() * 1e3,
                outcome,
            );
        };
        let removes: Vec<String> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Remove { path, .. } => Some(path.clone()),
                _ => None,
            })
            .collect();
        // The write set arbitration defends: everything this commit adds
        // or tombstones, plus the app transactions it stamps.
        let write_set: HashSet<&str> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Add(f) => Some(f.path.as_str()),
                Action::Remove { path, .. } => Some(path.as_str()),
                _ => None,
            })
            .collect();
        let our_txns: Vec<(&str, u64)> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Txn { app_id, version } => Some((app_id.as_str(), *version)),
                _ => None,
            })
            .collect();
        // Serialize with co-located writers before spending any
        // object-store round-trips; a full queue is a typed conflict.
        let _slot = match self.queue_slot() {
            Ok(slot) => slot,
            Err(e) => {
                journal(None, 0, "conflict");
                return Err(e);
            }
        };
        // Validate removes against the current snapshot up front: removing a
        // file that is not live means the caller planned against a stale view.
        if !removes.is_empty() {
            let snap = self.snapshot()?;
            for r in &removes {
                if !snap.files.contains_key(r) {
                    journal(None, 0, "conflict");
                    return Err(anyhow::Error::new(CommitConflict {
                        table: self.root.clone(),
                        version: Some(snap.version),
                        reason: format!("cannot remove {r}: not live in snapshot"),
                    }));
                }
            }
        }
        let body = commit_to_ndjson(&actions);
        let mut retries = 0u64;
        let mut rebases = 0u64;
        let mut replayed = read_version;
        let mut version = read_version + 1;
        loop {
            // Arbitrate everything that landed since the read snapshot (or
            // the last replay) — BEFORE the put, so a plan gone stale while
            // waiting in the local queue is classified without burning a
            // round-trip on a doomed `put_if_absent`.
            let latest = self.latest_version()?;
            if latest > replayed {
                for v in replayed + 1..=latest {
                    let text = String::from_utf8(self.store.get(&self.commit_key(v))?)
                        .context("commit not utf8")?;
                    if let Err(e) =
                        classify_winner(&self.root, v, &commit_from_ndjson(&text)?, &write_set, &our_txns)
                    {
                        journal(None, retries, "conflict");
                        return Err(e);
                    }
                }
                replayed = latest;
                // Every winner is disjoint from us: rebase onto the new
                // log position and re-commit the same body.
                rebases += 1;
                COMMIT_REBASES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if rebases > rebase_max() {
                    journal(None, retries, "conflict");
                    return Err(anyhow::Error::new(CommitConflict {
                        table: self.root.clone(),
                        version: Some(latest),
                        reason: format!("rebase budget exhausted after {} rounds", rebases - 1),
                    }));
                }
                version = (latest + 1).max(version);
            }
            if self.store.put_if_absent(&self.commit_key(version), body.as_bytes())? {
                if version % CHECKPOINT_INTERVAL == 0 {
                    // Best-effort checkpoint; failure must not fail the
                    // commit, but it must not be invisible either — the
                    // doctor/probe surface checkpoint lag from the journal.
                    if self.write_checkpoint(version).is_err() {
                        self.journal("CHECKPOINT", Some(version), 0, 0, 0, 0, 0.0, "error");
                    }
                }
                journal(Some(version), retries, if rebases > 0 { "rebased" } else { "ok" });
                return Ok(version);
            }
            // Lost the race for `version`: count it and loop — the replay
            // above will classify the winner(s) and move us past them.
            COMMIT_RETRIES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            retries += 1;
            self.store.io_span().retry();
            if retries as usize >= MAX_COMMIT_RETRIES {
                journal(None, retries, "conflict");
                return Err(anyhow::Error::new(CommitConflict {
                    table: self.root.clone(),
                    version: None,
                    reason: format!("giving up after {MAX_COMMIT_RETRIES} lost races"),
                }));
            }
            version += 1;
        }
    }

    /// Acquire this table's in-process commit slot (None when the queue is
    /// disabled via `DT_COMMIT_QUEUE=0`).
    fn queue_slot(&self) -> Result<Option<QueueGuard>> {
        let depth = commit_queue_depth();
        if depth == 0 {
            return Ok(None);
        }
        let key = (self.store.instance_id(), self.root.clone());
        let q = {
            let mut map = COMMIT_QUEUES.lock().unwrap();
            Arc::clone(map.entry(key).or_insert_with(|| {
                Arc::new(TableQueue {
                    busy: std::sync::Mutex::new(false),
                    cv: std::sync::Condvar::new(),
                    waiters: std::sync::atomic::AtomicU64::new(0),
                })
            }))
        };
        q.acquire(&self.root, depth).map(Some)
    }

    /// Record one [`crate::health::journal`] event for an operation against
    /// this table.
    #[allow(clippy::too_many_arguments)]
    fn journal(
        &self,
        op: &str,
        version: Option<u64>,
        adds: usize,
        removes: usize,
        bytes: u64,
        retries: u64,
        duration_ms: f64,
        outcome: &str,
    ) {
        crate::health::journal::record(crate::health::journal::JournalEvent {
            seq: 0,
            timestamp_ms: 0,
            instance: self.store.instance_id(),
            table: self.root.clone(),
            op: op.to_string(),
            version,
            adds,
            removes,
            bytes,
            retries,
            duration_ms,
            outcome: outcome.to_string(),
        });
    }

    /// Snapshot at the latest version.
    pub fn snapshot(&self) -> Result<Snapshot> {
        let v = self.latest_version()?;
        self.snapshot_at(v)
    }

    /// Snapshot at a specific version (time travel).
    pub fn snapshot_at(&self, version: u64) -> Result<Snapshot> {
        ensure!(
            self.store.head(&self.commit_key(version))?.is_some(),
            "version {version} does not exist"
        );
        // Find the newest checkpoint at or before `version`.
        let mut start = 0u64;
        let mut files: BTreeMap<String, AddFile> = BTreeMap::new();
        let mut txns: BTreeMap<String, u64> = BTreeMap::new();
        let mut metadata: Option<Metadata> = None;
        if let Some((cv, snap_files, snap_txns, snap_meta)) = self.read_checkpoint_before(version)? {
            start = cv + 1;
            files = snap_files;
            txns = snap_txns;
            metadata = Some(snap_meta);
        }
        for v in start..=version {
            let body = self.store.get(&self.commit_key(v))?;
            let text = String::from_utf8(body).context("commit not utf8")?;
            for action in commit_from_ndjson(&text)? {
                apply_action(&mut files, &mut txns, &mut metadata, action);
            }
        }
        Ok(Snapshot {
            version,
            metadata: metadata.context("no metaData action found in log")?,
            files,
            txns,
        })
    }

    /// Version history: (version, operation, timestamp) tuples, newest last.
    pub fn history(&self) -> Result<Vec<(u64, String, i64)>> {
        let latest = self.latest_version()?;
        let mut out = Vec::new();
        for v in 0..=latest {
            if self.store.head(&self.commit_key(v))?.is_none() {
                continue;
            }
            let text = String::from_utf8(self.store.get(&self.commit_key(v))?)?;
            let mut op = String::new();
            let mut ts = 0i64;
            for action in commit_from_ndjson(&text)? {
                if let Action::CommitInfo { operation, timestamp } = action {
                    op = operation;
                    ts = timestamp;
                }
            }
            out.push((v, op, ts));
        }
        Ok(out)
    }

    fn write_checkpoint(&self, version: u64) -> Result<()> {
        let snap = self.snapshot_at(version)?;
        let files: Vec<Json> = snap
            .files
            .values()
            .map(|f| Action::Add(f.clone()).to_json())
            .collect();
        let txns: Vec<Json> = snap
            .txns
            .iter()
            .map(|(app_id, v)| {
                Action::Txn { app_id: app_id.clone(), version: *v }.to_json()
            })
            .collect();
        let j = Json::obj([
            ("version", Json::from(version)),
            ("metaData", Action::Metadata(snap.metadata.clone()).to_json()),
            ("files", Json::Arr(files)),
            ("txns", Json::Arr(txns)),
        ]);
        self.store.put(&self.checkpoint_key(version), j.dump().as_bytes())?;
        let hint = Json::obj([("version", Json::from(version))]);
        self.store.put(&self.last_checkpoint_key(), hint.dump().as_bytes())?;
        Ok(())
    }

    #[allow(clippy::type_complexity)]
    fn read_checkpoint_before(
        &self,
        version: u64,
    ) -> Result<Option<(u64, BTreeMap<String, AddFile>, BTreeMap<String, u64>, Metadata)>> {
        // Use the _last_checkpoint hint, falling back to a list scan.
        let mut candidate: Option<u64> = None;
        if let Some(len) = self.store.head(&self.last_checkpoint_key())? {
            let _ = len;
            let body = self.store.get(&self.last_checkpoint_key())?;
            if let Ok(j) = jsonx::parse(std::str::from_utf8(&body).unwrap_or("")) {
                if let Some(v) = j.get("version").and_then(Json::as_u64) {
                    if v <= version {
                        candidate = Some(v);
                    }
                }
            }
        }
        if candidate.is_none() {
            for k in self.store.list(&self.log_prefix())? {
                if let Some(v) = parse_checkpoint_version(&k) {
                    if v <= version {
                        candidate = Some(candidate.map_or(v, |c: u64| c.max(v)));
                    }
                }
            }
        }
        let Some(cv) = candidate else { return Ok(None) };
        let body = match self.store.get(&self.checkpoint_key(cv)) {
            Ok(b) => b,
            Err(_) => return Ok(None), // stale hint; replay full log
        };
        let j = jsonx::parse(std::str::from_utf8(&body).context("checkpoint not utf8")?)?;
        let mut files = BTreeMap::new();
        let mut txns = BTreeMap::new();
        let mut metadata = None;
        if let Some(m) = j.get("metaData") {
            if let Action::Metadata(md) = Action::from_json(m)? {
                metadata = Some(md);
            }
        }
        for f in j.get("files").and_then(Json::as_arr).unwrap_or(&[]) {
            if let Action::Add(a) = Action::from_json(f)? {
                files.insert(a.path.clone(), a);
            }
        }
        // Older checkpoints (pre-txn) simply have no `txns` array.
        for t in j.get("txns").and_then(Json::as_arr).unwrap_or(&[]) {
            if let Action::Txn { app_id, version } = Action::from_json(t)? {
                txns.insert(app_id, version);
            }
        }
        let metadata = metadata.context("checkpoint missing metaData")?;
        Ok(Some((cv, files, txns, metadata)))
    }

    /// Delete objects no longer referenced by the snapshot ("VACUUM"):
    /// returns the number deleted. Sweeps everything under the table root
    /// except the transaction log itself, so every artifact family the
    /// log tracks — tensor part files under `data/`, ANN index artifacts
    /// under `index/`, and whatever future tiers add — is reclaimed
    /// without this list needing maintenance.
    pub fn vacuum(&self) -> Result<usize> {
        let started = std::time::Instant::now();
        let snap = self.snapshot()?;
        let live: std::collections::HashSet<&str> =
            snap.files.keys().map(|s| s.as_str()).collect();
        let log = self.log_prefix();
        let mut deleted = 0usize;
        let mut freed = 0u64;
        for key in self.store.list(&format!("{}/", self.root))? {
            if key.starts_with(&log) {
                continue;
            }
            let rel = key.strip_prefix(&format!("{}/", self.root)).unwrap_or(&key);
            if !live.contains(rel) {
                freed += self.store.head(&key)?.unwrap_or(0);
                self.store.delete(&key)?;
                deleted += 1;
            }
        }
        // VACUUM never commits, so it journals directly: `removes` counts
        // swept objects, `bytes` the storage they occupied.
        self.journal(
            "VACUUM",
            Some(snap.version),
            0,
            deleted,
            freed,
            0,
            started.elapsed().as_secs_f64() * 1e3,
            "ok",
        );
        Ok(deleted)
    }
}

/// Cache of materialized [`Snapshot`]s keyed by `(store instance, table
/// root)`, always serving the table's **latest** version.
///
/// A hit costs one LIST (the version probe) instead of replaying the whole
/// log; when the table has advanced, only the commits past the cached
/// version are replayed on top of the cached state (incremental refresh).
/// This is the read engine's answer to every read path calling
/// `table.snapshot()` — often twice — per request.
pub struct SnapshotCache {
    map: std::sync::Mutex<std::collections::HashMap<(u64, String), Arc<Snapshot>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl Default for SnapshotCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotCache {
    /// Maximum cached tables before the map is cleared (one entry per
    /// `(store, root)` pair; hot deployments hold a handful).
    const CAPACITY: usize = 1024;

    /// New empty cache.
    pub fn new() -> Self {
        Self {
            map: std::sync::Mutex::new(std::collections::HashMap::new()),
            hits: std::sync::atomic::AtomicU64::new(0),
            misses: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// The latest snapshot of `table`, from cache when still current.
    pub fn get(&self, table: &DeltaTable) -> Result<Arc<Snapshot>> {
        use std::sync::atomic::Ordering;
        let latest = table.latest_version()?;
        let key = (table.store().instance_id(), table.root().to_string());
        let cached = self.map.lock().unwrap().get(&key).cloned();
        if let Some(snap) = cached {
            if snap.version == latest {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(snap);
            }
            if snap.version < latest {
                // Incremental refresh: replay only the new commits.
                self.hits.fetch_add(1, Ordering::Relaxed);
                let mut files = snap.files.clone();
                let mut txns = snap.txns.clone();
                let mut metadata = Some(snap.metadata.clone());
                for v in snap.version + 1..=latest {
                    let body = table.store().get(&table.commit_key(v))?;
                    let text = String::from_utf8(body).context("commit not utf8")?;
                    for action in commit_from_ndjson(&text)? {
                        apply_action(&mut files, &mut txns, &mut metadata, action);
                    }
                }
                let fresh = Arc::new(Snapshot {
                    version: latest,
                    metadata: metadata.context("no metaData action found in log")?,
                    files,
                    txns,
                });
                self.insert(key, fresh.clone());
                return Ok(fresh);
            }
            // cached version ahead of `latest` can only mean the key was
            // reused for a different table — fall through and rebuild.
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let fresh = Arc::new(table.snapshot_at(latest)?);
        self.insert(key, fresh.clone());
        Ok(fresh)
    }

    fn insert(&self, key: (u64, String), snap: Arc<Snapshot>) {
        let mut map = self.map.lock().unwrap();
        if map.len() >= Self::CAPACITY {
            map.clear();
        }
        map.insert(key, snap);
    }

    /// Cache hits so far (including incremental refreshes).
    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Full-replay misses so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }
}

fn apply_action(
    files: &mut BTreeMap<String, AddFile>,
    txns: &mut BTreeMap<String, u64>,
    metadata: &mut Option<Metadata>,
    action: Action,
) {
    match action {
        Action::Add(a) => {
            files.insert(a.path.clone(), a);
        }
        Action::Remove { path, .. } => {
            files.remove(&path);
        }
        Action::Txn { app_id, version } => {
            let v = txns.entry(app_id).or_insert(version);
            *v = (*v).max(version);
        }
        Action::Metadata(m) => *metadata = Some(m),
        Action::Protocol { .. } | Action::CommitInfo { .. } => {}
    }
}

/// Classify one winner commit against our write set and app transactions:
/// `Ok(())` means provably disjoint (safe to rebase past), `Err` carries a
/// typed [`CommitConflict`] naming the first overlap found.
fn classify_winner(
    table: &str,
    winner_version: u64,
    winners: &[Action],
    write_set: &HashSet<&str>,
    our_txns: &[(&str, u64)],
) -> Result<()> {
    let conflict = |reason: String| {
        Err(anyhow::Error::new(CommitConflict {
            table: table.to_string(),
            version: Some(winner_version),
            reason,
        }))
    };
    for a in winners {
        match a {
            Action::Add(f) if write_set.contains(f.path.as_str()) => {
                return conflict(format!("winner also wrote {}", f.path));
            }
            Action::Remove { path, .. } if write_set.contains(path.as_str()) => {
                return conflict(format!("winner removed {path}"));
            }
            Action::Txn { app_id, version } => {
                if let Some((_, ours)) =
                    our_txns.iter().find(|(id, _)| id == app_id)
                {
                    if version >= ours {
                        return conflict(format!(
                            "winner applied txn {app_id}@{version} (ours covers {ours})"
                        ));
                    }
                }
            }
            _ => {}
        }
    }
    Ok(())
}

fn parse_commit_version(key: &str) -> Option<u64> {
    let name = key.rsplit('/').next()?;
    let digits = name.strip_suffix(".json")?;
    if digits.len() == 20 && digits.bytes().all(|b| b.is_ascii_digit()) {
        digits.parse().ok()
    } else {
        None
    }
}

fn parse_checkpoint_version(key: &str) -> Option<u64> {
    let name = key.rsplit('/').next()?;
    let digits = name.strip_suffix(".checkpoint.json")?;
    if digits.len() == 20 && digits.bytes().all(|b| b.is_ascii_digit()) {
        digits.parse().ok()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add(path: &str, tensor: &str, lo: i64, hi: i64) -> Action {
        Action::Add(AddFile {
            path: path.into(),
            size: 100,
            rows: 10,
            tensor_id: tensor.into(),
            min_key: Some(lo),
            max_key: Some(hi),
            timestamp: now_ms(),
            meta: None,
        })
    }

    fn info(op: &str) -> Action {
        Action::CommitInfo { operation: op.into(), timestamp: now_ms() }
    }

    #[test]
    fn create_open_and_commit() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store.clone(), "tbl").unwrap();
        assert_eq!(t.latest_version().unwrap(), 0);
        let v = t.commit(vec![add("data/a.dtpq", "t1", 0, 9), info("WRITE")]).unwrap();
        assert_eq!(v, 1);
        let t2 = DeltaTable::open(store, "tbl").unwrap();
        let snap = t2.snapshot().unwrap();
        assert_eq!(snap.version, 1);
        assert_eq!(snap.files.len(), 1);
        assert_eq!(snap.files_for_tensor("t1").len(), 1);
        assert_eq!(snap.total_rows(), 10);
    }

    #[test]
    fn double_create_fails() {
        let store = ObjectStoreHandle::mem();
        DeltaTable::create(store.clone(), "tbl").unwrap();
        assert!(DeltaTable::create(store, "tbl").is_err());
    }

    #[test]
    fn open_missing_fails() {
        assert!(DeltaTable::open(ObjectStoreHandle::mem(), "nope").is_err());
    }

    #[test]
    fn remove_drops_file() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        t.commit(vec![add("data/a", "t1", 0, 9)]).unwrap();
        t.commit(vec![Action::Remove { path: "data/a".into(), timestamp: now_ms() }]).unwrap();
        assert!(t.snapshot().unwrap().files.is_empty());
    }

    #[test]
    fn time_travel_sees_old_files() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        let v1 = t.commit(vec![add("data/a", "t1", 0, 9)]).unwrap();
        let v2 = t
            .commit(vec![
                Action::Remove { path: "data/a".into(), timestamp: now_ms() },
                add("data/b", "t1", 0, 9),
            ])
            .unwrap();
        let s1 = t.snapshot_at(v1).unwrap();
        assert!(s1.files.contains_key("data/a"));
        let s2 = t.snapshot_at(v2).unwrap();
        assert!(!s2.files.contains_key("data/a"));
        assert!(s2.files.contains_key("data/b"));
        assert!(t.snapshot_at(99).is_err());
    }

    #[test]
    fn concurrent_commits_all_land() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        let mut handles = Vec::new();
        for i in 0..8 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                t.commit(vec![add(&format!("data/f{i}"), "t1", 0, 9), info("WRITE")]).unwrap()
            }));
        }
        let mut versions: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        versions.sort_unstable();
        versions.dedup();
        assert_eq!(versions.len(), 8, "every commit must get a distinct version");
        assert_eq!(t.snapshot().unwrap().files.len(), 8);
    }

    #[test]
    fn conflicting_remove_fails() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        t.commit(vec![add("data/a", "t1", 0, 9)]).unwrap();
        // Simulate a concurrent winner removing data/a between our read and commit:
        // we take the version, then another commit removes the file, then we try.
        let other = t.clone();
        other
            .commit(vec![Action::Remove { path: "data/a".into(), timestamp: now_ms() }])
            .unwrap();
        // Now our commit that also removes data/a must observe the conflict.
        // First put_if_absent attempt will succeed at a fresh version, so force
        // a conflict by pre-claiming the next version.
        let v = t.latest_version().unwrap();
        t.store.put(&t.commit_key(v + 1), b"{\"commitInfo\":{\"operation\":\"X\",\"timestamp\":0}}\n")
            .unwrap();
        let res = t.commit(vec![Action::Remove { path: "data/a".into(), timestamp: now_ms() }]);
        assert!(res.is_err(), "double remove after conflict must fail");
    }

    /// A store whose first conditional PUT of a commit (version >= 1) is
    /// preceded by a rival landing a burst of commits longer than the
    /// retry budget — the race window between a writer's version probe and
    /// its `put_if_absent`, stretched to worst case.
    struct BurstRival {
        inner: crate::objectstore::MemStore,
        fired: std::sync::atomic::AtomicBool,
    }

    impl ObjectStore for BurstRival {
        fn put(&self, key: &str, data: &[u8]) -> Result<()> {
            self.inner.put(key, data)
        }
        fn put_if_absent(&self, key: &str, data: &[u8]) -> Result<bool> {
            if let Some(v) = parse_commit_version(key) {
                if v >= 1 && !self.fired.swap(true, std::sync::atomic::Ordering::SeqCst) {
                    let dir = &key[..key.rfind('/').unwrap() + 1];
                    for r in 0..(MAX_COMMIT_RETRIES as u64 + 8) {
                        let rival = format!("{dir}{:020}.json", v + r);
                        let body =
                            b"{\"commitInfo\":{\"operation\":\"RIVAL\",\"timestamp\":0}}\n";
                        self.inner.put_if_absent(&rival, body)?;
                    }
                }
            }
            self.inner.put_if_absent(key, data)
        }
        fn get(&self, key: &str) -> Result<Vec<u8>> {
            self.inner.get(key)
        }
        fn get_range(&self, key: &str, off: u64, len: u64) -> Result<Vec<u8>> {
            self.inner.get_range(key, off, len)
        }
        fn head(&self, key: &str) -> Result<Option<u64>> {
            self.inner.head(key)
        }
        fn list(&self, prefix: &str) -> Result<Vec<String>> {
            self.inner.list(prefix)
        }
        fn delete(&self, key: &str) -> Result<()> {
            self.inner.delete(key)
        }
    }

    #[test]
    fn lost_race_retries_against_refreshed_log_position() {
        // Regression: the loser of a put_if_absent burst must refresh the
        // log position and land past the winners, not step one version at
        // a time until the retry budget runs out.
        let store = ObjectStoreHandle::new(Arc::new(BurstRival {
            inner: crate::objectstore::MemStore::new(),
            fired: std::sync::atomic::AtomicBool::new(false),
        }));
        let t = DeltaTable::create(store, "tbl").unwrap();
        let retries_before = commit_retry_count();
        let v = t.commit(vec![add("data/a", "t1", 0, 9), info("WRITE")]).unwrap();
        assert_eq!(
            v,
            1 + MAX_COMMIT_RETRIES as u64 + 8,
            "commit must land after the rival burst"
        );
        assert!(commit_retry_count() > retries_before, "the lost race must be counted");
        let snap = t.snapshot().unwrap();
        assert!(snap.files.contains_key("data/a"));
    }

    /// A store whose first conditional PUT of a commit at version >=
    /// `trigger` is preceded by a rival landing `rival_body` at exactly
    /// that version — a deterministic single-commit race.
    struct InjectRival {
        inner: crate::objectstore::MemStore,
        trigger: u64,
        rival_body: Vec<u8>,
        fired: std::sync::atomic::AtomicBool,
    }

    impl ObjectStore for InjectRival {
        fn put(&self, key: &str, data: &[u8]) -> Result<()> {
            self.inner.put(key, data)
        }
        fn put_if_absent(&self, key: &str, data: &[u8]) -> Result<bool> {
            if let Some(v) = parse_commit_version(key) {
                if v >= self.trigger
                    && !self.fired.swap(true, std::sync::atomic::Ordering::SeqCst)
                {
                    self.inner.put_if_absent(key, &self.rival_body)?;
                }
            }
            self.inner.put_if_absent(key, data)
        }
        fn get(&self, key: &str) -> Result<Vec<u8>> {
            self.inner.get(key)
        }
        fn get_range(&self, key: &str, off: u64, len: u64) -> Result<Vec<u8>> {
            self.inner.get_range(key, off, len)
        }
        fn head(&self, key: &str) -> Result<Option<u64>> {
            self.inner.head(key)
        }
        fn list(&self, prefix: &str) -> Result<Vec<String>> {
            self.inner.list(prefix)
        }
        fn delete(&self, key: &str) -> Result<()> {
            self.inner.delete(key)
        }
    }

    fn inject_rival(trigger: u64, rival: &[Action]) -> ObjectStoreHandle {
        ObjectStoreHandle::new(Arc::new(InjectRival {
            inner: crate::objectstore::MemStore::new(),
            trigger,
            rival_body: commit_to_ndjson(rival).into_bytes(),
            fired: std::sync::atomic::AtomicBool::new(false),
        }))
    }

    #[test]
    fn txn_lands_in_snapshot_and_survives_checkpoint() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        t.commit(vec![
            Action::Txn { app_id: "index/v".into(), version: 0 },
            info("BUILD INDEX"),
        ])
        .unwrap();
        assert_eq!(t.snapshot().unwrap().txn_version("index/v"), Some(0));
        // A later txn for the same app raises the recorded version; an
        // out-of-order replay of an older one must not lower it.
        t.commit(vec![Action::Txn { app_id: "index/v".into(), version: 7 }]).unwrap();
        t.commit(vec![Action::Txn { app_id: "index/v".into(), version: 3 }]).unwrap();
        assert_eq!(t.snapshot().unwrap().txn_version("index/v"), Some(7));
        // Push past a checkpoint boundary: the txn table must ride the
        // checkpoint, not only the replayed tail.
        for i in 0..10 {
            t.commit(vec![add(&format!("data/f{i}"), "t1", i, i), info("WRITE")]).unwrap();
        }
        let v = t.latest_version().unwrap();
        assert!(t.store.head(&t.checkpoint_key(10)).unwrap().is_some());
        let snap = t.snapshot_at(v).unwrap();
        assert_eq!(snap.txn_version("index/v"), Some(7));
        assert_eq!(snap.txn_version("index/other"), None);
    }

    #[test]
    fn disjoint_race_rebases_without_client_visible_failure() {
        let store = inject_rival(1, &[add("data/rival", "t2", 0, 9), info("WRITE")]);
        let t = DeltaTable::create(store, "tbl").unwrap();
        let rebases_before = commit_rebase_count();
        let v = t.commit(vec![add("data/mine", "t1", 0, 9), info("WRITE")]).unwrap();
        assert_eq!(v, 2, "loser must land right after the disjoint winner");
        assert!(commit_rebase_count() > rebases_before, "the rebase must be counted");
        let snap = t.snapshot().unwrap();
        assert!(snap.files.contains_key("data/mine"));
        assert!(snap.files.contains_key("data/rival"), "winner's work must survive");
        let ev = crate::health::journal::events(Some(t.store.instance_id()), Some("tbl"));
        assert!(
            ev.iter().any(|e| e.outcome == "rebased" && e.version == Some(2)),
            "journal must record the rebased outcome: {ev:?}"
        );
    }

    #[test]
    fn overlapping_race_surfaces_typed_conflict() {
        // The rival adds the very path we want to add: not rebasable.
        let store = inject_rival(1, &[add("data/same", "t1", 0, 9), info("WRITE")]);
        let t = DeltaTable::create(store, "tbl").unwrap();
        let err = t
            .commit(vec![add("data/same", "t1", 0, 9), info("WRITE")])
            .expect_err("overlapping write must not silently land");
        let conflict = err
            .downcast_ref::<CommitConflict>()
            .expect("error must downcast to CommitConflict");
        assert_eq!(conflict.table, "tbl");
        assert_eq!(conflict.version, Some(1));
        assert!(conflict.reason.contains("data/same"), "{conflict}");
    }

    #[test]
    fn racing_txn_for_same_app_surfaces_typed_conflict() {
        // The rival stamps the same app transaction at the same covered
        // version — our plan is redundant and must be refused, not
        // last-write-wins.
        let store = inject_rival(
            1,
            &[
                add("index/v/rival.idx", "", 0, 0),
                Action::Txn { app_id: "index/v".into(), version: 0 },
                info("BUILD INDEX"),
            ],
        );
        let t = DeltaTable::create(store, "tbl").unwrap();
        let err = t
            .commit(vec![
                add("index/v/mine.idx", "", 0, 0),
                Action::Txn { app_id: "index/v".into(), version: 0 },
                info("BUILD INDEX"),
            ])
            .expect_err("racing same-app txn must conflict");
        let conflict = err.downcast_ref::<CommitConflict>().unwrap();
        assert!(conflict.reason.contains("index/v"), "{conflict}");
        // The winner's artifact set is intact; ours never landed.
        let snap = t.snapshot().unwrap();
        assert!(snap.files.contains_key("index/v/rival.idx"));
        assert!(!snap.files.contains_key("index/v/mine.idx"));
    }

    #[test]
    fn stale_plan_against_newer_txn_refused_before_any_put() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        let read_version = t.latest_version().unwrap();
        // A fresher writer applies the app txn for data version 3.
        t.commit(vec![Action::Txn { app_id: "index/v".into(), version: 3 }]).unwrap();
        // Our plan (made at `read_version`, covering only version 1) is
        // stale: arbitration must refuse it during replay, without
        // attempting a single put.
        let err = t
            .commit_from(
                vec![Action::Txn { app_id: "index/v".into(), version: 1 }, info("FOLD INDEX")],
                read_version,
            )
            .expect_err("stale txn plan must be refused");
        let conflict = err.downcast_ref::<CommitConflict>().unwrap();
        assert!(conflict.reason.contains("index/v@3"), "{conflict}");
        let retries_key = t.commit_key(t.latest_version().unwrap() + 1);
        assert!(t.store.head(&retries_key).unwrap().is_none(), "no put may have landed");
    }

    #[test]
    fn checkpoint_roundtrip_and_stale_hint() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        for i in 0..25 {
            t.commit(vec![add(&format!("data/f{i}"), "t1", i, i), info("WRITE")]).unwrap();
        }
        // Versions 10 and 20 should have checkpoints.
        assert!(t.store.head(&t.checkpoint_key(10)).unwrap().is_some());
        assert!(t.store.head(&t.checkpoint_key(20)).unwrap().is_some());
        let snap = t.snapshot().unwrap();
        assert_eq!(snap.files.len(), 25);
        // Time travel to before the first checkpoint still works.
        let s5 = t.snapshot_at(5).unwrap();
        assert_eq!(s5.files.len(), 5);
        // And to a mid-checkpoint version.
        let s15 = t.snapshot_at(15).unwrap();
        assert_eq!(s15.files.len(), 15);
    }

    #[test]
    fn history_lists_operations() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store, "tbl").unwrap();
        t.commit(vec![add("data/a", "t", 0, 0), info("WRITE")]).unwrap();
        t.commit(vec![info("OPTIMIZE")]).unwrap();
        let h = t.history().unwrap();
        assert_eq!(h.len(), 3);
        assert_eq!(h[0].1, "CREATE TABLE");
        assert_eq!(h[1].1, "WRITE");
        assert_eq!(h[2].1, "OPTIMIZE");
    }

    #[test]
    fn vacuum_deletes_unreferenced_objects() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store.clone(), "tbl").unwrap();
        store.put("tbl/data/live.dtpq", b"x").unwrap();
        store.put("tbl/data/dead.dtpq", b"x").unwrap();
        t.commit(vec![add("data/live.dtpq", "t", 0, 0)]).unwrap();
        let n = t.vacuum().unwrap();
        assert_eq!(n, 1);
        assert!(store.head("tbl/data/live.dtpq").unwrap().is_some());
        assert!(store.head("tbl/data/dead.dtpq").unwrap().is_none());
    }

    #[test]
    fn now_ms_is_strictly_monotonic() {
        let a = now_ms();
        let b = now_ms();
        let c = now_ms();
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn snapshot_cache_serves_and_refreshes_incrementally() {
        let store = ObjectStoreHandle::mem();
        let t = DeltaTable::create(store.clone(), "tbl").unwrap();
        t.commit(vec![add("data/a", "t1", 0, 9), info("WRITE")]).unwrap();
        let cache = SnapshotCache::new();
        let s1 = cache.get(&t).unwrap();
        assert_eq!(s1.files.len(), 1);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // Same version: pure hit, and no commit-body GETs.
        store.stats().reset();
        let s2 = cache.get(&t).unwrap();
        assert_eq!(s2.version, s1.version);
        assert_eq!(cache.hits(), 1);
        assert_eq!(store.stats().snapshot().0, 0, "hit must not GET commit bodies");
        // Advance the table: incremental refresh replays only the new commit.
        t.commit(vec![add("data/b", "t1", 0, 9), info("WRITE")]).unwrap();
        store.stats().reset();
        let s3 = cache.get(&t).unwrap();
        assert_eq!(s3.files.len(), 2);
        assert_eq!(store.stats().snapshot().0, 1, "refresh replays exactly the new commit");
        assert_eq!(cache.misses(), 1, "refresh is not a full replay");
        // Cached result matches a from-scratch snapshot.
        let direct = t.snapshot().unwrap();
        assert_eq!(s3.files.keys().collect::<Vec<_>>(), direct.files.keys().collect::<Vec<_>>());
    }

    #[test]
    fn version_key_parsing() {
        assert_eq!(parse_commit_version("tbl/_delta_log/00000000000000000042.json"), Some(42));
        assert_eq!(parse_commit_version("tbl/_delta_log/_last_checkpoint"), None);
        assert_eq!(
            parse_checkpoint_version("tbl/_delta_log/00000000000000000010.checkpoint.json"),
            Some(10)
        );
        assert_eq!(parse_checkpoint_version("tbl/_delta_log/00000000000000000010.json"), None);
    }
}
