//! Delta log actions — the JSON records that make up each commit, mirroring
//! the open-source Delta Lake protocol (`protocol`, `metaData`, `add`,
//! `remove`, `commitInfo`), reduced to the fields this system uses.

use crate::jsonx::Json;
use crate::Result;
use anyhow::{bail, Context};

/// A data file referenced by the table, with pruning statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct AddFile {
    /// Object-store key relative to the table root.
    pub path: String,
    /// File size in bytes.
    pub size: u64,
    /// Number of logical rows.
    pub rows: u64,
    /// Tensor id this file belongs to ("" when mixed).
    pub tensor_id: String,
    /// Min value of the leading pruning key (e.g. first-dim index / chunk idx).
    pub min_key: Option<i64>,
    /// Max value of the leading pruning key.
    pub max_key: Option<i64>,
    /// Commit timestamp (ms since epoch).
    pub timestamp: i64,
    /// Optional format metadata (JSON: dense shape, dtype, ...) so readers
    /// can reconstruct empty tensors without any data rows.
    pub meta: Option<String>,
}

/// Table metadata (the `metaData` action).
#[derive(Debug, Clone, PartialEq)]
pub struct Metadata {
    /// Stable table id.
    pub id: String,
    /// Human-readable name.
    pub name: String,
    /// Free-form schema descriptor (the tensor formats document their
    /// column layout here; schema evolution appends keys).
    pub schema: Json,
    /// Creation timestamp (ms since epoch).
    pub created: i64,
}

/// One action in a commit.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Protocol version marker.
    Protocol {
        /// Minimum reader version.
        min_reader: i64,
        /// Minimum writer version.
        min_writer: i64,
    },
    /// Table metadata (re-emitted on schema evolution).
    Metadata(Metadata),
    /// Add a data file.
    Add(AddFile),
    /// Remove a data file (tombstone).
    Remove {
        /// Path of the removed file.
        path: String,
        /// Deletion timestamp (ms since epoch).
        timestamp: i64,
    },
    /// Informational commit provenance.
    CommitInfo {
        /// Operation name ("WRITE", "OPTIMIZE", ...).
        operation: String,
        /// Timestamp (ms since epoch).
        timestamp: i64,
    },
    /// Application transaction marker (the protocol's `txn` action): records
    /// that application `app_id` has applied its work for data version
    /// `version`. Index builds/folds and append upkeep stamp one of these so
    /// a racing or stale writer for the same `app_id` is detected by the
    /// commit arbitration instead of silently overwriting fresher artifacts.
    Txn {
        /// Application id (e.g. `index/<tensor>`).
        app_id: String,
        /// Highest data version this application has covered.
        version: u64,
    },
}

impl Action {
    /// Serialize to the single-line JSON object used in the log.
    pub fn to_json(&self) -> Json {
        match self {
            Action::Protocol { min_reader, min_writer } => Json::obj([(
                "protocol",
                Json::obj([
                    ("minReaderVersion", Json::Int(*min_reader)),
                    ("minWriterVersion", Json::Int(*min_writer)),
                ]),
            )]),
            Action::Metadata(m) => Json::obj([(
                "metaData",
                Json::obj([
                    ("id", Json::from(m.id.as_str())),
                    ("name", Json::from(m.name.as_str())),
                    ("schema", m.schema.clone()),
                    ("createdTime", Json::Int(m.created)),
                ]),
            )]),
            Action::Add(a) => {
                let mut fields = vec![
                    ("path", Json::from(a.path.as_str())),
                    ("size", Json::from(a.size)),
                    ("rows", Json::from(a.rows)),
                    ("tensorId", Json::from(a.tensor_id.as_str())),
                    ("modificationTime", Json::Int(a.timestamp)),
                ];
                if let (Some(lo), Some(hi)) = (a.min_key, a.max_key) {
                    fields.push(("minKey", Json::Int(lo)));
                    fields.push(("maxKey", Json::Int(hi)));
                }
                if let Some(m) = &a.meta {
                    fields.push(("meta", Json::from(m.as_str())));
                }
                Json::obj([("add", Json::obj(fields))])
            }
            Action::Remove { path, timestamp } => Json::obj([(
                "remove",
                Json::obj([
                    ("path", Json::from(path.as_str())),
                    ("deletionTimestamp", Json::Int(*timestamp)),
                ]),
            )]),
            Action::CommitInfo { operation, timestamp } => Json::obj([(
                "commitInfo",
                Json::obj([
                    ("operation", Json::from(operation.as_str())),
                    ("timestamp", Json::Int(*timestamp)),
                ]),
            )]),
            Action::Txn { app_id, version } => Json::obj([(
                "txn",
                Json::obj([
                    ("appId", Json::from(app_id.as_str())),
                    ("version", Json::from(*version)),
                ]),
            )]),
        }
    }

    /// Parse a single action object.
    pub fn from_json(j: &Json) -> Result<Action> {
        if let Some(p) = j.get("protocol") {
            return Ok(Action::Protocol {
                min_reader: p.get("minReaderVersion").and_then(Json::as_i64).unwrap_or(1),
                min_writer: p.get("minWriterVersion").and_then(Json::as_i64).unwrap_or(1),
            });
        }
        if let Some(m) = j.get("metaData") {
            return Ok(Action::Metadata(Metadata {
                id: m.get("id").and_then(Json::as_str).context("metaData.id")?.to_string(),
                name: m.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                schema: m.get("schema").cloned().unwrap_or(Json::Null),
                created: m.get("createdTime").and_then(Json::as_i64).unwrap_or(0),
            }));
        }
        if let Some(a) = j.get("add") {
            return Ok(Action::Add(AddFile {
                path: a.get("path").and_then(Json::as_str).context("add.path")?.to_string(),
                size: a.get("size").and_then(Json::as_u64).unwrap_or(0),
                rows: a.get("rows").and_then(Json::as_u64).unwrap_or(0),
                tensor_id: a.get("tensorId").and_then(Json::as_str).unwrap_or("").to_string(),
                min_key: a.get("minKey").and_then(Json::as_i64),
                max_key: a.get("maxKey").and_then(Json::as_i64),
                timestamp: a.get("modificationTime").and_then(Json::as_i64).unwrap_or(0),
                meta: a.get("meta").and_then(Json::as_str).map(str::to_string),
            }));
        }
        if let Some(r) = j.get("remove") {
            return Ok(Action::Remove {
                path: r.get("path").and_then(Json::as_str).context("remove.path")?.to_string(),
                timestamp: r.get("deletionTimestamp").and_then(Json::as_i64).unwrap_or(0),
            });
        }
        if let Some(c) = j.get("commitInfo") {
            return Ok(Action::CommitInfo {
                operation: c.get("operation").and_then(Json::as_str).unwrap_or("").to_string(),
                timestamp: c.get("timestamp").and_then(Json::as_i64).unwrap_or(0),
            });
        }
        if let Some(t) = j.get("txn") {
            return Ok(Action::Txn {
                app_id: t.get("appId").and_then(Json::as_str).context("txn.appId")?.to_string(),
                version: t.get("version").and_then(Json::as_u64).unwrap_or(0),
            });
        }
        bail!("unrecognized action: {}", j.dump())
    }
}

/// Serialize a commit (one action per line, newline-terminated).
pub fn commit_to_ndjson(actions: &[Action]) -> String {
    let mut out = String::new();
    for a in actions {
        out.push_str(&a.to_json().dump());
        out.push('\n');
    }
    out
}

/// Parse a commit file.
pub fn commit_from_ndjson(text: &str) -> Result<Vec<Action>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Action::from_json(&crate::jsonx::parse(l)?))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_actions() -> Vec<Action> {
        vec![
            Action::Protocol { min_reader: 1, min_writer: 2 },
            Action::Metadata(Metadata {
                id: "tbl-1".into(),
                name: "tensors".into(),
                schema: Json::obj([("format", Json::from("ftsf"))]),
                created: 1700000000000,
            }),
            Action::Add(AddFile {
                path: "data/part-0.dtpq".into(),
                size: 4096,
                rows: 24,
                tensor_id: "6e368".into(),
                min_key: Some(0),
                max_key: Some(23),
                timestamp: 1700000000001,
                meta: Some(r#"{"shape":[24,3,1024,1024]}"#.into()),
            }),
            Action::Remove { path: "data/old.dtpq".into(), timestamp: 1700000000002 },
            Action::CommitInfo { operation: "WRITE".into(), timestamp: 1700000000003 },
            Action::Txn { app_id: "index/6e368".into(), version: 4 },
        ]
    }

    #[test]
    fn action_json_roundtrip() {
        for a in sample_actions() {
            let j = a.to_json();
            let back = Action::from_json(&j).unwrap();
            assert_eq!(back, a, "{}", j.dump());
        }
    }

    #[test]
    fn ndjson_roundtrip() {
        let actions = sample_actions();
        let text = commit_to_ndjson(&actions);
        assert_eq!(text.lines().count(), actions.len());
        assert_eq!(commit_from_ndjson(&text).unwrap(), actions);
    }

    #[test]
    fn add_without_stats_roundtrips() {
        let a = Action::Add(AddFile {
            path: "p".into(),
            size: 1,
            rows: 1,
            tensor_id: "".into(),
            min_key: None,
            max_key: None,
            timestamp: 0,
            meta: None,
        });
        assert_eq!(Action::from_json(&a.to_json()).unwrap(), a);
    }

    #[test]
    fn unknown_action_rejected() {
        let j = crate::jsonx::parse(r#"{"cdc":{"path":"x"}}"#).unwrap();
        assert!(Action::from_json(&j).is_err());
    }

    #[test]
    fn txn_missing_version_defaults_to_zero() {
        let j = crate::jsonx::parse(r#"{"txn":{"appId":"x"}}"#).unwrap();
        assert_eq!(
            Action::from_json(&j).unwrap(),
            Action::Txn { app_id: "x".into(), version: 0 }
        );
    }

    #[test]
    fn blank_lines_ignored() {
        let text = "\n{\"commitInfo\":{\"operation\":\"W\",\"timestamp\":1}}\n\n";
        assert_eq!(commit_from_ndjson(text).unwrap().len(), 1);
    }
}
