//! Sparse COO tensors — the in-memory representation of the paper's sparse
//! workloads (`torch.sparse_coo_tensor` equivalent): nnz coordinates plus
//! values, with the dense shape carried alongside for exact reconstruction.

use super::{numel, DType, DenseTensor, Slice};
use crate::Result;
use anyhow::ensure;

/// A sparse tensor in coordinate (COO) format.
///
/// `indices` is nnz rows × ndim columns, flattened row-major (the paper's
/// Figure 5 layout: one coordinate tuple per non-zero). Values are f64
/// internally; the original dtype is preserved for round-trips.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseCoo {
    dtype: DType,
    shape: Vec<usize>,
    /// nnz × ndim coordinate matrix, row-major.
    indices: Vec<u32>,
    /// nnz values.
    values: Vec<f64>,
}

impl SparseCoo {
    /// Build from parallel coordinate/value arrays.
    pub fn new(
        dtype: DType,
        shape: &[usize],
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Self> {
        let ndim = shape.len();
        ensure!(ndim > 0, "sparse tensor must have rank >= 1");
        ensure!(
            indices.len() == values.len() * ndim,
            "indices length {} != nnz {} * ndim {}",
            indices.len(),
            values.len(),
            ndim
        );
        for (r, row) in indices.chunks_exact(ndim).enumerate() {
            for (d, (&ix, &size)) in row.iter().zip(shape).enumerate() {
                ensure!(
                    (ix as usize) < size,
                    "nnz {r}: index {ix} out of bounds in dim {d} (size {size})"
                );
            }
        }
        Ok(Self { dtype, shape: shape.to_vec(), indices, values })
    }

    /// Element dtype of the equivalent dense tensor.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Dense shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// nnz × ndim coordinates, row-major.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Values parallel to [`Self::indices`].
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Coordinate row `r`.
    pub fn coord(&self, r: usize) -> &[u32] {
        &self.indices[r * self.ndim()..(r + 1) * self.ndim()]
    }

    /// Fraction of non-zero elements.
    pub fn density(&self) -> f64 {
        let n = numel(&self.shape);
        if n == 0 {
            0.0
        } else {
            self.nnz() as f64 / n as f64
        }
    }

    /// Sort entries lexicographically by coordinate (canonical order used by
    /// the encoders; CSF construction requires it). Stable for duplicate
    /// detection downstream.
    pub fn sort_canonical(&mut self) {
        let ndim = self.ndim();
        let nnz = self.nnz();
        let mut order: Vec<usize> = (0..nnz).collect();
        let idx = &self.indices;
        order.sort_by(|&a, &b| idx[a * ndim..(a + 1) * ndim].cmp(&idx[b * ndim..(b + 1) * ndim]));
        let mut new_idx = Vec::with_capacity(self.indices.len());
        let mut new_val = Vec::with_capacity(nnz);
        for &r in &order {
            new_idx.extend_from_slice(&self.indices[r * ndim..(r + 1) * ndim]);
            new_val.push(self.values[r]);
        }
        self.indices = new_idx;
        self.values = new_val;
    }

    /// True if entries are in canonical (lexicographic) coordinate order.
    pub fn is_sorted(&self) -> bool {
        let ndim = self.ndim();
        (1..self.nnz()).all(|r| {
            self.indices[(r - 1) * ndim..r * ndim] <= self.indices[r * ndim..(r + 1) * ndim]
        })
    }

    /// Materialize to a dense tensor.
    pub fn to_dense(&self) -> Result<DenseTensor> {
        let mut out = DenseTensor::zeros(self.dtype, &self.shape);
        let ndim = self.ndim();
        let mut idx = vec![0usize; ndim];
        for r in 0..self.nnz() {
            for d in 0..ndim {
                idx[d] = self.indices[r * ndim + d] as usize;
            }
            out.set_from_f64(&idx, self.values[r])?;
        }
        Ok(out)
    }

    /// Build from a dense tensor by scanning non-zeros (canonical order).
    pub fn from_dense(t: &DenseTensor) -> Result<Self> {
        let shape = t.shape().to_vec();
        let ndim = shape.len();
        ensure!(ndim > 0, "rank-0 tensors not supported");
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut idx = vec![0usize; ndim];
        for flat in 0..t.numel() {
            let mut off = flat;
            for d in (0..ndim).rev() {
                idx[d] = off % shape[d];
                off /= shape[d];
            }
            let v = t.get_as_f64(&idx)?;
            if v != 0.0 {
                indices.extend(idx.iter().map(|&i| i as u32));
                values.push(v);
            }
        }
        Self::new(t.dtype(), &shape, indices, values)
    }

    /// Restrict to a slice, producing a sparse tensor of the sliced shape
    /// with re-based coordinates.
    pub fn slice(&self, slice: &Slice) -> Result<SparseCoo> {
        let ranges = slice.resolve(&self.shape)?;
        let out_shape: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
        let ndim = self.ndim();
        let mut indices = Vec::new();
        let mut values = Vec::new();
        'rows: for r in 0..self.nnz() {
            let row = self.coord(r);
            for (d, range) in ranges.iter().enumerate() {
                let ix = row[d] as usize;
                if ix < range.start || ix >= range.end {
                    continue 'rows;
                }
            }
            for (d, range) in ranges.iter().enumerate() {
                indices.push(row[d] - range.start as u32);
            }
            let _ = ndim;
            values.push(self.values[r]);
        }
        SparseCoo::new(self.dtype, &out_shape, indices, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SparseCoo {
        // Paper Figure 5: shape [3,3,3] with 4 nnz.
        SparseCoo::new(
            DType::F32,
            &[3, 3, 3],
            vec![0, 0, 1, 1, 0, 0, 1, 1, 2, 2, 2, 2],
            vec![1.0, 2.0, 3.0, 4.0],
        )
        .unwrap()
    }

    #[test]
    fn construction_validates() {
        assert!(SparseCoo::new(DType::F32, &[2, 2], vec![0, 0, 1], vec![1.0]).is_err());
        assert!(SparseCoo::new(DType::F32, &[2, 2], vec![0, 2], vec![1.0]).is_err());
        assert!(SparseCoo::new(DType::F32, &[], vec![], vec![]).is_err());
    }

    #[test]
    fn dense_roundtrip() {
        let s = sample();
        let d = s.to_dense().unwrap();
        assert_eq!(d.get_as_f64(&[0, 0, 1]).unwrap(), 1.0);
        assert_eq!(d.get_as_f64(&[2, 2, 2]).unwrap(), 4.0);
        assert_eq!(d.count_nonzero(), 4);
        let s2 = SparseCoo::from_dense(&d).unwrap();
        assert_eq!(s2.nnz(), 4);
        assert_eq!(s2.to_dense().unwrap(), d);
    }

    #[test]
    fn density() {
        let s = sample();
        assert!((s.density() - 4.0 / 27.0).abs() < 1e-12);
    }

    #[test]
    fn sort_canonical_orders_rows() {
        let mut s = SparseCoo::new(
            DType::F64,
            &[4, 4],
            vec![3, 1, 0, 2, 1, 1],
            vec![30.0, 2.0, 11.0],
        )
        .unwrap();
        assert!(!s.is_sorted());
        s.sort_canonical();
        assert!(s.is_sorted());
        assert_eq!(s.coord(0), &[0, 2]);
        assert_eq!(s.values(), &[2.0, 11.0, 30.0]);
    }

    #[test]
    fn slice_rebases_coordinates() {
        let s = sample();
        let sl = s.slice(&Slice::index(1)).unwrap();
        assert_eq!(sl.shape(), &[1, 3, 3]);
        assert_eq!(sl.nnz(), 2);
        let d = sl.to_dense().unwrap();
        assert_eq!(d.get_as_f64(&[0, 0, 0]).unwrap(), 2.0);
        assert_eq!(d.get_as_f64(&[0, 1, 2]).unwrap(), 3.0);
    }

    #[test]
    fn slice_equivalence_with_dense() {
        let s = sample();
        let slice = Slice::ranges(&[(0, 2), (0, 2)]);
        let via_sparse = s.slice(&slice).unwrap().to_dense().unwrap();
        let via_dense = s.to_dense().unwrap().slice(&slice).unwrap();
        assert_eq!(via_sparse, via_dense);
    }

    #[test]
    fn from_dense_empty() {
        let d = DenseTensor::zeros(DType::F32, &[3, 3]);
        let s = SparseCoo::from_dense(&d).unwrap();
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.to_dense().unwrap(), d);
    }
}
