//! Dense n-dimensional tensors stored as contiguous row-major bytes —
//! the in-memory equivalent of `numpy.ndarray` in the paper's pipeline.

use super::{linearize, numel, DType, Slice};
use crate::Result;
use anyhow::{bail, ensure};

/// A contiguous row-major dense tensor.
///
/// Data is held as raw little-endian bytes plus a dtype, which makes
/// (de)serialization to the storage formats zero-copy where possible and
/// keeps one concrete type across all dtypes.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseTensor {
    dtype: DType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl DenseTensor {
    /// Build a tensor from raw little-endian bytes.
    pub fn from_bytes(dtype: DType, shape: &[usize], data: Vec<u8>) -> Result<Self> {
        ensure!(
            data.len() == numel(shape) * dtype.size(),
            "byte length {} does not match shape {:?} of dtype {}",
            data.len(),
            shape,
            dtype.name()
        );
        Ok(Self { dtype, shape: shape.to_vec(), data })
    }

    /// All-zeros tensor.
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        Self { dtype, shape: shape.to_vec(), data: vec![0u8; numel(shape) * dtype.size()] }
    }

    /// Build an f32 tensor from values.
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Result<Self> {
        ensure!(values.len() == numel(shape), "value count mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self::from_bytes(DType::F32, shape, data)
    }

    /// Build an f64 tensor from values.
    pub fn from_f64(shape: &[usize], values: &[f64]) -> Result<Self> {
        ensure!(values.len() == numel(shape), "value count mismatch");
        let mut data = Vec::with_capacity(values.len() * 8);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self::from_bytes(DType::F64, shape, data)
    }

    /// Build a u8 tensor from values.
    pub fn from_u8(shape: &[usize], values: Vec<u8>) -> Result<Self> {
        ensure!(values.len() == numel(shape), "value count mismatch");
        Self::from_bytes(DType::U8, shape, values)
    }

    /// Element type.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Shape (sizes per dimension).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    /// Raw little-endian bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Consume into raw bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.data
    }

    /// Total byte size of the payload.
    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    /// View as f32 values (dtype must be F32).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == DType::F32, "dtype is {}", self.dtype.name());
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// View as f64 values (dtype must be F64).
    pub fn as_f64(&self) -> Result<Vec<f64>> {
        ensure!(self.dtype == DType::F64, "dtype is {}", self.dtype.name());
        Ok(self.data.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Element at a multi-index as f64 (any dtype).
    pub fn get_as_f64(&self, index: &[usize]) -> Result<f64> {
        ensure!(index.len() == self.shape.len(), "rank mismatch");
        for (i, (&ix, &d)) in index.iter().zip(&self.shape).enumerate() {
            ensure!(ix < d, "index {ix} out of bounds for dim {i} (size {d})");
        }
        let off = linearize(index, &self.shape) * self.dtype.size();
        Ok(match self.dtype {
            DType::U8 => self.data[off] as f64,
            DType::I32 => {
                i32::from_le_bytes(self.data[off..off + 4].try_into().unwrap()) as f64
            }
            DType::I64 => {
                i64::from_le_bytes(self.data[off..off + 8].try_into().unwrap()) as f64
            }
            DType::F32 => {
                f32::from_le_bytes(self.data[off..off + 4].try_into().unwrap()) as f64
            }
            DType::F64 => f64::from_le_bytes(self.data[off..off + 8].try_into().unwrap()),
        })
    }

    /// Set the element at a multi-index from an f64 (any dtype; lossy for
    /// integer dtypes via truncation toward zero).
    pub fn set_from_f64(&mut self, index: &[usize], v: f64) -> Result<()> {
        ensure!(index.len() == self.shape.len(), "rank mismatch");
        let off = linearize(index, &self.shape) * self.dtype.size();
        match self.dtype {
            DType::U8 => self.data[off] = v as u8,
            DType::I32 => self.data[off..off + 4].copy_from_slice(&(v as i32).to_le_bytes()),
            DType::I64 => self.data[off..off + 8].copy_from_slice(&(v as i64).to_le_bytes()),
            DType::F32 => self.data[off..off + 4].copy_from_slice(&(v as f32).to_le_bytes()),
            DType::F64 => self.data[off..off + 8].copy_from_slice(&v.to_le_bytes()),
        }
        Ok(())
    }

    /// Reshape in place (same number of elements).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        ensure!(numel(shape) == self.numel(), "reshape changes element count");
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Extract a contiguous sub-tensor described by `slice` (one range per
    /// dimension). Copies row-fragments with memcpy-sized moves.
    pub fn slice(&self, slice: &Slice) -> Result<DenseTensor> {
        let ranges = slice.resolve(&self.shape)?;
        let out_shape: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
        let esize = self.dtype.size();
        let mut out = Vec::with_capacity(numel(&out_shape) * esize);

        // The innermost dimension range is contiguous in memory: iterate the
        // outer dims' cartesian product and memcpy inner runs.
        if self.shape.is_empty() {
            return DenseTensor::from_bytes(self.dtype, &[], self.data.clone());
        }
        if out_shape.iter().any(|&d| d == 0) {
            // Empty selection in some dimension: no bytes to copy.
            return Ok(DenseTensor::zeros(self.dtype, &out_shape));
        }
        let inner = ranges.last().unwrap().clone();
        let inner_bytes = (inner.end - inner.start) * esize;
        let outer_ranges = &ranges[..ranges.len() - 1];
        let mut idx: Vec<usize> = outer_ranges.iter().map(|r| r.start).collect();
        let strides = super::strides_for(&self.shape);
        loop {
            // offset of (idx..., inner.start)
            let mut off = inner.start;
            for (i, &ix) in idx.iter().enumerate() {
                off += ix * strides[i];
            }
            let start = off * esize;
            out.extend_from_slice(&self.data[start..start + inner_bytes]);
            // increment the outer multi-index
            let mut dim = idx.len();
            loop {
                if dim == 0 {
                    return DenseTensor::from_bytes(self.dtype, &out_shape, out);
                }
                dim -= 1;
                idx[dim] += 1;
                if idx[dim] < outer_ranges[dim].end {
                    break;
                }
                idx[dim] = outer_ranges[dim].start;
            }
        }
    }

    /// Count of non-zero elements (used to decide sparse vs dense routing).
    pub fn count_nonzero(&self) -> usize {
        let esize = self.dtype.size();
        self.data.chunks_exact(esize).filter(|c| c.iter().any(|&b| b != 0)).count()
    }

    /// Fraction of non-zero elements in [0, 1].
    pub fn density(&self) -> f64 {
        if self.numel() == 0 {
            return 0.0;
        }
        self.count_nonzero() as f64 / self.numel() as f64
    }
}

impl DenseTensor {
    /// Validate internal invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<()> {
        if self.data.len() != self.numel() * self.dtype.size() {
            bail!("data length inconsistent with shape");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = DenseTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.ndim(), 2);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.byte_len(), 24);
        assert_eq!(t.get_as_f64(&[1, 2]).unwrap(), 6.0);
        assert_eq!(t.as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(DenseTensor::from_f32(&[2, 3], &[1., 2.]).is_err());
        assert!(DenseTensor::from_bytes(DType::F32, &[2], vec![0u8; 7]).is_err());
    }

    #[test]
    fn get_set_all_dtypes() {
        for dtype in [DType::U8, DType::I32, DType::I64, DType::F32, DType::F64] {
            let mut t = DenseTensor::zeros(dtype, &[3, 3]);
            t.set_from_f64(&[1, 1], 42.0).unwrap();
            assert_eq!(t.get_as_f64(&[1, 1]).unwrap(), 42.0, "{}", dtype.name());
            assert_eq!(t.get_as_f64(&[0, 0]).unwrap(), 0.0);
        }
    }

    #[test]
    fn out_of_bounds_get_rejected() {
        let t = DenseTensor::zeros(DType::F32, &[2, 2]);
        assert!(t.get_as_f64(&[2, 0]).is_err());
        assert!(t.get_as_f64(&[0]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = DenseTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.clone().reshape(&[3, 2]).unwrap();
        assert_eq!(r.as_f32().unwrap(), t.as_f32().unwrap());
        assert!(t.clone().reshape(&[4, 2]).is_err());
    }

    #[test]
    fn slice_middle_block() {
        // 4x4 matrix, slice rows 1..3, cols 2..4.
        let vals: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let t = DenseTensor::from_f32(&[4, 4], &vals).unwrap();
        let s = t.slice(&Slice::ranges(&[(1, 3), (2, 4)])).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.as_f32().unwrap(), vec![6., 7., 10., 11.]);
    }

    #[test]
    fn slice_full_is_identity() {
        let vals: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let t = DenseTensor::from_f32(&[2, 3, 4], &vals).unwrap();
        let s = t.slice(&Slice::all(3)).unwrap();
        assert_eq!(s, t);
    }

    #[test]
    fn slice_first_dim_prefix() {
        let vals: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let t = DenseTensor::from_f32(&[4, 3, 2], &vals).unwrap();
        let s = t.slice(&Slice::prefix(0, 2, 3)).unwrap();
        assert_eq!(s.shape(), &[2, 3, 2]);
        assert_eq!(s.as_f32().unwrap(), (0..12).map(|x| x as f32).collect::<Vec<_>>());
    }

    #[test]
    fn density_and_nonzero() {
        let t = DenseTensor::from_f32(&[2, 2], &[0., 1., 0., 2.]).unwrap();
        assert_eq!(t.count_nonzero(), 2);
        assert!((t.density() - 0.5).abs() < 1e-12);
        let z = DenseTensor::zeros(DType::U8, &[10]);
        assert_eq!(z.count_nonzero(), 0);
    }

    #[test]
    fn slice_1d() {
        let t = DenseTensor::from_f32(&[5], &[0., 1., 2., 3., 4.]).unwrap();
        let s = t.slice(&Slice::ranges(&[(1, 4)])).unwrap();
        assert_eq!(s.as_f32().unwrap(), vec![1., 2., 3.]);
    }
}
