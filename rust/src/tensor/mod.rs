//! Tensor data model: dtypes, dense tensors, sparse COO tensors and slice
//! specifications — the in-memory representations that the storage formats
//! in [`crate::formats`] encode and decode.

mod dense;
mod slice;
mod sparse;

pub use dense::DenseTensor;
pub use slice::{Dim, Slice};
pub use sparse::SparseCoo;

use anyhow::bail;

/// Element type of a tensor. Matches the numpy/PyTorch dtypes the paper's
/// datasets use (u8 images, f32/f64 values, i64 indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// Unsigned 8-bit (images).
    U8,
    /// Signed 32-bit integer.
    I32,
    /// Signed 64-bit integer (indices, counts).
    I64,
    /// IEEE-754 single precision.
    F32,
    /// IEEE-754 double precision.
    F64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::U8 => 1,
            DType::I32 | DType::F32 => 4,
            DType::I64 | DType::F64 => 8,
        }
    }

    /// Stable name used in table metadata.
    pub fn name(self) -> &'static str {
        match self {
            DType::U8 => "u8",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    /// Parse a [`DType::name`].
    pub fn parse(s: &str) -> crate::Result<DType> {
        Ok(match s {
            "u8" => DType::U8,
            "i32" => DType::I32,
            "i64" => DType::I64,
            "f32" => DType::F32,
            "f64" => DType::F64,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// Number of elements implied by a shape (product of dims).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major (C-order) strides for a shape, in elements.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

/// Linearize a multi-index into a row-major offset.
#[inline]
pub fn linearize(index: &[usize], shape: &[usize]) -> usize {
    debug_assert_eq!(index.len(), shape.len());
    let mut off = 0usize;
    for (i, (&ix, &d)) in index.iter().zip(shape).enumerate() {
        debug_assert!(ix < d, "index {ix} out of bounds for dim {i} of size {d}");
        off = off * d + ix;
    }
    off
}

/// Inverse of [`linearize`]: decompose a flat offset into a multi-index.
pub fn delinearize(mut off: usize, shape: &[usize]) -> Vec<usize> {
    let mut idx = vec![0usize; shape.len()];
    for i in (0..shape.len()).rev() {
        idx[i] = off % shape[i];
        off /= shape[i];
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::U8, DType::I32, DType::I64, DType::F32, DType::F64] {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("f16").is_err());
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_for(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_for(&[5]), vec![1]);
        assert_eq!(strides_for(&[]), Vec::<usize>::new());
    }

    #[test]
    fn linearize_delinearize_inverse() {
        let shape = [3, 4, 5];
        for off in 0..numel(&shape) {
            let idx = delinearize(off, &shape);
            assert_eq!(linearize(&idx, &shape), off);
        }
    }

    #[test]
    fn linearize_matches_strides() {
        let shape = [2, 3, 4];
        let strides = strides_for(&shape);
        let idx = [1, 2, 3];
        let manual: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        assert_eq!(linearize(&idx, &shape), manual);
    }
}
