//! Slice specifications — the paper's §III notation `X_S` (fix a prefix of
//! indices / take ranges per dimension), e.g. `X[0:100, :, :, :]`.

use crate::Result;
use anyhow::ensure;
use std::ops::Range;

/// Per-dimension selection: the full dimension, a half-open range, or a
/// single index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dim {
    /// The whole dimension (`:`).
    All,
    /// A half-open range `[start, end)`.
    Range(usize, usize),
    /// A single index `i` — equivalent to `Range(i, i + 1)` and resolved to
    /// the width-1 window `(i, i)` by read planning, so `X[i]` prunes
    /// exactly like the formats' min/max pruning does.
    Index(usize),
}

/// A slice over an n-dimensional tensor: one [`Dim`] per dimension.
///
/// `Slice::ranges(&[(0,100)])` on a rank-4 tensor means `X[0:100,:,:,:]` —
/// unspecified trailing dimensions default to `All`, matching the paper's
/// convention of omitting full dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Slice {
    dims: Vec<Dim>,
}

impl Slice {
    /// Select everything in a rank-`ndim` tensor.
    pub fn all(ndim: usize) -> Self {
        Self { dims: vec![Dim::All; ndim] }
    }

    /// Build from explicit (start, end) pairs; trailing dims default to All
    /// when resolved against a higher-rank shape.
    pub fn ranges(ranges: &[(usize, usize)]) -> Self {
        Self { dims: ranges.iter().map(|&(s, e)| Dim::Range(s, e)).collect() }
    }

    /// A single index in dimension 0 (the paper's `X[i,:,:,:]` read-slice
    /// workload): `index(3)` is `X[3:4, ...]`.
    pub fn index(i: usize) -> Self {
        Self { dims: vec![Dim::Index(i)] }
    }

    /// Range `[start, end)` in dimension `dim`, everything elsewhere, for a
    /// rank-`ndim` tensor.
    pub fn prefix(dim: usize, end: usize, ndim: usize) -> Self {
        let mut dims = vec![Dim::All; ndim];
        dims[dim] = Dim::Range(0, end);
        Self { dims }
    }

    /// Range in dimension 0: `X[start:end, ...]`.
    pub fn dim0(start: usize, end: usize) -> Self {
        Self { dims: vec![Dim::Range(start, end)] }
    }

    /// The per-dimension selections provided so far.
    pub fn dims(&self) -> &[Dim] {
        &self.dims
    }

    /// Resolve against a concrete shape into per-dimension ranges,
    /// validating bounds. Missing trailing dims become full ranges.
    pub fn resolve(&self, shape: &[usize]) -> Result<Vec<Range<usize>>> {
        ensure!(
            self.dims.len() <= shape.len(),
            "slice rank {} exceeds tensor rank {}",
            self.dims.len(),
            shape.len()
        );
        let mut out = Vec::with_capacity(shape.len());
        for (i, &d) in shape.iter().enumerate() {
            let r = match self.dims.get(i) {
                None | Some(Dim::All) => 0..d,
                Some(&Dim::Range(s, e)) => {
                    ensure!(s <= e, "slice dim {i}: start {s} > end {e}");
                    ensure!(e <= d, "slice dim {i}: end {e} out of bounds (size {d})");
                    s..e
                }
                Some(&Dim::Index(ix)) => {
                    ensure!(ix < d, "slice dim {i}: index {ix} out of bounds (size {d})");
                    ix..ix + 1
                }
            };
            out.push(r);
        }
        Ok(out)
    }

    /// The range selected in dimension 0 once resolved (convenience for
    /// formats that prune on the leading dimension).
    pub fn dim0_range(&self, shape: &[usize]) -> Result<Range<usize>> {
        Ok(self.resolve(shape)?.remove(0))
    }

    /// Whether this slice selects the entire tensor of the given shape.
    pub fn is_full(&self, shape: &[usize]) -> bool {
        match self.resolve(shape) {
            Ok(rs) => rs.iter().zip(shape).all(|(r, &d)| r.start == 0 && r.end == d),
            Err(_) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_defaults_trailing_to_all() {
        let s = Slice::ranges(&[(0, 2)]);
        let rs = s.resolve(&[5, 6, 7]).unwrap();
        assert_eq!(rs, vec![0..2, 0..6, 0..7]);
    }

    #[test]
    fn index_slice() {
        let s = Slice::index(3);
        assert_eq!(s.dims(), &[Dim::Index(3)]);
        assert_eq!(s.resolve(&[10, 4]).unwrap(), vec![3..4, 0..4]);
        assert!(Slice::index(10).resolve(&[10]).is_err(), "index out of bounds");
    }

    #[test]
    fn bounds_checked() {
        assert!(Slice::ranges(&[(0, 11)]).resolve(&[10]).is_err());
        assert!(Slice::ranges(&[(5, 3)]).resolve(&[10]).is_err());
        assert!(Slice::ranges(&[(0, 1), (0, 1)]).resolve(&[10]).is_err());
    }

    #[test]
    fn empty_range_allowed() {
        let s = Slice::ranges(&[(3, 3)]);
        assert_eq!(s.resolve(&[10]).unwrap(), vec![3..3]);
    }

    #[test]
    fn is_full_detection() {
        assert!(Slice::all(3).is_full(&[2, 3, 4]));
        assert!(Slice::ranges(&[(0, 2)]).is_full(&[2, 3]));
        assert!(!Slice::ranges(&[(0, 1)]).is_full(&[2, 3]));
    }
}
