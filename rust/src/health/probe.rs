//! Fleet health probe: cheap per-table gauges, no data reads.
//!
//! [`probe`] costs O(snapshot) — the engine's cached snapshot, two
//! metadata sweeps (`list` + `head`, no GETs) for byte totals, and a walk
//! of the resident block cache — so the closed-loop harnesses can sample
//! it per round and BENCH reports can carry health *trajectories*:
//!
//! * **space amplification** — bytes physically under the table (outside
//!   the log) over bytes the snapshot references; OPTIMIZE/VACUUM debt;
//! * **delta-segment fan-out** and **index staleness age** in versions —
//!   the auto-fold trigger inputs;
//! * **log length since the last checkpoint** — replay cost on a cold
//!   open;
//! * the **cache heatmap**: the top-K hottest resident blocks for this
//!   store instance.
//!
//! The last probe's gauges park in [`crate::health`]'s statics so the
//! `stats` tier report renders them without re-probing.

use crate::delta::DeltaTable;
use crate::jsonx::Json;
use crate::Result;
use once_cell::sync::Lazy;

/// Default cache-heatmap depth when `DT_PROBE_TOPK` is unset.
pub const DEFAULT_PROBE_TOPK: usize = 8;

/// Heatmap depth in effect (`DT_PROBE_TOPK`, default
/// [`DEFAULT_PROBE_TOPK`]).
pub fn top_k() -> usize {
    static ENV: Lazy<usize> = Lazy::new(|| {
        std::env::var("DT_PROBE_TOPK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_PROBE_TOPK)
    });
    *ENV
}

/// One probe's gauges for one table.
#[derive(Debug, Clone)]
pub struct ProbeReport {
    /// Table root probed.
    pub table: String,
    /// Snapshot version the gauges describe.
    pub version: u64,
    /// Store instance the table lives on.
    pub instance: u64,
    /// Bytes the snapshot references (live data + index artifacts).
    pub live_bytes: u64,
    /// Bytes physically under the root outside `_delta_log/`.
    pub physical_bytes: u64,
    /// Bytes under `_delta_log/`.
    pub log_bytes: u64,
    /// `physical_bytes / live_bytes` (1.0 when the table is empty):
    /// OPTIMIZE/VACUUM debt. Healthy tables sit at 1.0; orphans and
    /// un-vacuumed rewrites push it up.
    pub space_amp: f64,
    /// Live files in the snapshot.
    pub live_files: u64,
    /// Live delta posting segments across all indexes.
    pub delta_segments: u64,
    /// Indexes whose fingerprint no longer matches the live data.
    pub stale_indexes: u64,
    /// Max versions elapsed since a stale index's build (0 when all fresh).
    pub staleness_age: u64,
    /// Commits since the last checkpoint (cold-open replay cost).
    pub log_since_checkpoint: u64,
    /// Hottest resident cache blocks for this instance:
    /// `(path, off, len, hits)`.
    pub hot_blocks: Vec<(String, u64, u64, u64)>,
    /// Wall milliseconds the probe took.
    pub elapsed_ms: f64,
}

impl ProbeReport {
    /// JSON object form (embedded in BENCH/HEALTH documents).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("table", Json::from(self.table.as_str())),
            ("version", Json::from(self.version)),
            ("live_bytes", Json::from(self.live_bytes)),
            ("physical_bytes", Json::from(self.physical_bytes)),
            ("log_bytes", Json::from(self.log_bytes)),
            ("space_amp", Json::Float(self.space_amp)),
            ("live_files", Json::from(self.live_files)),
            ("delta_segments", Json::from(self.delta_segments)),
            ("stale_indexes", Json::from(self.stale_indexes)),
            ("staleness_age", Json::from(self.staleness_age)),
            ("log_since_checkpoint", Json::from(self.log_since_checkpoint)),
            (
                "hot_blocks",
                Json::Arr(
                    self.hot_blocks
                        .iter()
                        .map(|(p, off, len, hits)| {
                            Json::obj([
                                ("path", Json::from(p.as_str())),
                                ("off", Json::from(*off)),
                                ("len", Json::from(*len)),
                                ("hits", Json::from(*hits)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("elapsed_ms", Json::Float(self.elapsed_ms)),
        ])
    }

    /// Multi-line human rendering (the `stats`/`doctor` CLI surface).
    pub fn render(&self) -> String {
        let mut out = format!(
            "probe: {} @ v{} — {} live files, {} live B / {} physical B (amp {:.3}), \
             log {} B, {} commits since checkpoint\n\
               index: {} delta segment(s), {} stale (max age {} versions)\n",
            self.table,
            self.version,
            self.live_files,
            self.live_bytes,
            self.physical_bytes,
            self.space_amp,
            self.log_bytes,
            self.log_since_checkpoint,
            self.delta_segments,
            self.stale_indexes,
            self.staleness_age,
        );
        if !self.hot_blocks.is_empty() {
            out.push_str("  cache heatmap:\n");
            for (p, off, len, hits) in &self.hot_blocks {
                out.push_str(&format!("    {hits:>6} hits  {p} [{off}, {})\n", off + len));
            }
        }
        out
    }
}

/// Probe the table at its latest version. O(snapshot) + two metadata
/// sweeps; zero data GETs.
pub fn probe(table: &DeltaTable) -> Result<ProbeReport> {
    let started = std::time::Instant::now();
    let snap = crate::query::engine::snapshot(table)?;
    let store = table.store();
    let root_prefix = format!("{}/", table.root());
    let total = store.usage(&root_prefix)?;
    let log_bytes = store.usage(&table.log_prefix())?;
    let physical_bytes = total.saturating_sub(log_bytes);
    let live_bytes = snap.total_bytes();
    let space_amp = if live_bytes == 0 { 1.0 } else { physical_bytes as f64 / live_bytes as f64 };
    let (delta_segments, stale_indexes, staleness_age) = crate::index::health_gauges(&snap);
    let log_since_checkpoint = match table.last_checkpoint_version()? {
        Some(v) => snap.version.saturating_sub(v),
        None => snap.version + 1, // every commit since CREATE replays
    };
    let instance = store.instance_id();
    let report = ProbeReport {
        table: table.root().to_string(),
        version: snap.version,
        instance,
        live_bytes,
        physical_bytes,
        log_bytes,
        space_amp,
        live_files: snap.files.len() as u64,
        delta_segments,
        stale_indexes,
        staleness_age,
        log_since_checkpoint,
        hot_blocks: crate::serving::block_cache().hottest(instance, top_k()),
        elapsed_ms: started.elapsed().as_secs_f64() * 1e3,
    };
    crate::health::note_probe(&report);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_carries_every_gauge() {
        let r = ProbeReport {
            table: "t".into(),
            version: 7,
            instance: 3,
            live_bytes: 1000,
            physical_bytes: 1500,
            log_bytes: 90,
            space_amp: 1.5,
            live_files: 4,
            delta_segments: 2,
            stale_indexes: 1,
            staleness_age: 3,
            log_since_checkpoint: 5,
            hot_blocks: vec![("data/p.dtpq".into(), 0, 4096, 12)],
            elapsed_ms: 0.2,
        };
        let j = r.to_json();
        assert_eq!(j.get("version").and_then(Json::as_u64), Some(7));
        assert_eq!(j.get("space_amp").and_then(Json::as_f64), Some(1.5));
        assert_eq!(j.get("delta_segments").and_then(Json::as_u64), Some(2));
        assert_eq!(j.get("log_since_checkpoint").and_then(Json::as_u64), Some(5));
        let hot = j.get("hot_blocks").and_then(Json::as_arr).unwrap();
        assert_eq!(hot.len(), 1);
        assert_eq!(hot[0].get("hits").and_then(Json::as_u64), Some(12));
        let text = r.render();
        assert!(text.contains("amp 1.500") && text.contains("heatmap"), "{text}");
    }
}
