//! Structured event journal: one typed record per commit-shaped operation.
//!
//! Every commit that lands (or fails) through [`crate::delta::DeltaTable`]
//! — writes, appends, index builds and folds, OPTIMIZE — plus VACUUM
//! sweeps append a [`JournalEvent`] to a process-wide ring buffer, so
//! "what happened to this table" has an answer after the fact without
//! replaying span trees: the version it landed as, the operation name,
//! files added/removed, bytes, commit retries, wall duration and outcome.
//!
//! The ring is bounded by `DT_JOURNAL_KEEP` (default
//! [`DEFAULT_JOURNAL_KEEP`]); old events drop off the front and are
//! counted in [`dropped`]. Events carry the store instance id and table
//! root, so one process journaling many tables stays filterable. The
//! JSONL exporter ([`to_jsonl`]) renders one event per line for the
//! `history --journal --json` CLI surface and post-hoc tooling.

use crate::jsonx::Json;
use once_cell::sync::Lazy;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default ring capacity (events kept) when `DT_JOURNAL_KEEP` is unset.
pub const DEFAULT_JOURNAL_KEEP: usize = 256;

/// One journaled operation: the commit-shaped footprint of a write,
/// append, index build/fold, OPTIMIZE or VACUUM against one table.
#[derive(Debug, Clone)]
pub struct JournalEvent {
    /// Monotonic sequence number (process-wide, assigned at record time).
    pub seq: u64,
    /// Wall-clock milliseconds since the epoch at record time.
    pub timestamp_ms: i64,
    /// Store instance the table lives on.
    pub instance: u64,
    /// Table root prefix.
    pub table: String,
    /// Operation name (the CommitInfo operation, or `VACUUM`).
    pub op: String,
    /// Log version the operation landed as (`None` when it failed).
    pub version: Option<u64>,
    /// Add actions carried by the commit.
    pub adds: usize,
    /// Remove actions carried by the commit (or objects VACUUM deleted).
    pub removes: usize,
    /// Bytes referenced by the commit's Add actions.
    pub bytes: u64,
    /// `put_if_absent` races lost before the commit landed (or gave up).
    pub retries: u64,
    /// Wall milliseconds from first attempt to outcome.
    pub duration_ms: f64,
    /// `ok`, `rebased` (landed after at least one conflict-free rebase
    /// round), `conflict` (overlapping winner / stale txn / remove raced
    /// away / retry or rebase budget exhausted) or `error` (e.g. a
    /// `CHECKPOINT` event whose checkpoint write failed after the commit
    /// itself landed).
    pub outcome: String,
}

impl JournalEvent {
    /// JSON object form (one JSONL line's worth).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("seq", Json::from(self.seq)),
            ("ts_ms", Json::Int(self.timestamp_ms)),
            ("instance", Json::from(self.instance)),
            ("table", Json::from(self.table.as_str())),
            ("op", Json::from(self.op.as_str())),
        ];
        if let Some(v) = self.version {
            pairs.push(("version", Json::from(v)));
        }
        pairs.push(("adds", Json::from(self.adds)));
        pairs.push(("removes", Json::from(self.removes)));
        pairs.push(("bytes", Json::from(self.bytes)));
        pairs.push(("retries", Json::from(self.retries)));
        pairs.push(("duration_ms", Json::Float(self.duration_ms)));
        pairs.push(("outcome", Json::from(self.outcome.as_str())));
        Json::obj(pairs)
    }

    /// One-line human rendering (the `history --journal` row format).
    pub fn render(&self) -> String {
        let v = match self.version {
            Some(v) => format!("v{v}"),
            None => "-".to_string(),
        };
        format!(
            "{:>6}  {:<5} {:<14} {:>3}+ {:>3}- {:>10} B  {:>2} retries  {:>8.2} ms  {}",
            self.seq,
            v,
            self.op,
            self.adds,
            self.removes,
            self.bytes,
            self.retries,
            self.duration_ms,
            self.outcome
        )
    }
}

struct Journal {
    ring: Mutex<VecDeque<JournalEvent>>,
    cap: usize,
    seq: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

static JOURNAL: Lazy<Journal> = Lazy::new(|| Journal {
    ring: Mutex::new(VecDeque::new()),
    cap: keep_from_env(),
    seq: AtomicU64::new(0),
    recorded: AtomicU64::new(0),
    dropped: AtomicU64::new(0),
});

fn keep_from_env() -> usize {
    std::env::var("DT_JOURNAL_KEEP")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_JOURNAL_KEEP)
}

/// Append an event to the ring. `seq` and `timestamp_ms` are assigned
/// here; the caller fills everything else.
pub fn record(mut ev: JournalEvent) {
    let j = &*JOURNAL;
    ev.seq = j.seq.fetch_add(1, Ordering::Relaxed);
    ev.timestamp_ms = crate::delta::now_ms();
    j.recorded.fetch_add(1, Ordering::Relaxed);
    let mut ring = j.ring.lock().unwrap();
    while ring.len() >= j.cap {
        ring.pop_front();
        j.dropped.fetch_add(1, Ordering::Relaxed);
    }
    ring.push_back(ev);
}

/// Events currently in the ring, oldest first, optionally filtered to one
/// store instance and/or one table root.
pub fn events(instance: Option<u64>, table: Option<&str>) -> Vec<JournalEvent> {
    JOURNAL
        .ring
        .lock()
        .unwrap()
        .iter()
        .filter(|e| instance.map_or(true, |i| e.instance == i))
        .filter(|e| table.map_or(true, |t| e.table == t))
        .cloned()
        .collect()
}

/// Render events as JSONL: one `JournalEvent::to_json` document per line.
pub fn to_jsonl(events: &[JournalEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().dump());
        out.push('\n');
    }
    out
}

/// Events recorded so far, process-wide (including ones since evicted).
pub fn recorded() -> u64 {
    JOURNAL.recorded.load(Ordering::Relaxed)
}

/// Events evicted off the ring's front so far.
pub fn dropped() -> u64 {
    JOURNAL.dropped.load(Ordering::Relaxed)
}

/// Ring capacity in effect (`DT_JOURNAL_KEEP`).
pub fn keep() -> usize {
    JOURNAL.cap
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(table: &str, op: &str) -> JournalEvent {
        JournalEvent {
            seq: 0,
            timestamp_ms: 0,
            instance: 1,
            table: table.to_string(),
            op: op.to_string(),
            version: Some(3),
            adds: 2,
            removes: 1,
            bytes: 4096,
            retries: 0,
            duration_ms: 1.5,
            outcome: "ok".to_string(),
        }
    }

    #[test]
    fn record_assigns_sequence_and_filters_by_table() {
        record(ev("jr-a", "WRITE"));
        record(ev("jr-b", "OPTIMIZE"));
        record(ev("jr-a", "VACUUM"));
        let a = events(None, Some("jr-a"));
        assert_eq!(a.len(), 2);
        assert!(a[0].seq < a[1].seq, "sequence must be monotonic");
        assert_eq!(a[0].op, "WRITE");
        assert_eq!(a[1].op, "VACUUM");
        assert!(events(Some(999), Some("jr-a")).is_empty(), "instance filter");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        record(ev("jr-jsonl", "BUILD INDEX"));
        let evs = events(None, Some("jr-jsonl"));
        let text = to_jsonl(&evs);
        assert_eq!(text.lines().count(), evs.len());
        for line in text.lines() {
            let j = crate::jsonx::parse(line).expect("journal line must be valid JSON");
            assert_eq!(j.get("table").and_then(Json::as_str), Some("jr-jsonl"));
            assert_eq!(j.get("op").and_then(Json::as_str), Some("BUILD INDEX"));
            assert_eq!(j.get("version").and_then(Json::as_u64), Some(3));
            assert_eq!(j.get("outcome").and_then(Json::as_str), Some("ok"));
        }
    }

    #[test]
    fn render_mentions_op_and_outcome() {
        let e = ev("jr-render", "WRITE");
        let line = e.render();
        assert!(line.contains("WRITE") && line.contains("ok"), "{line}");
    }
}
