//! Storage-health observability: the *state* counterpart to the telemetry
//! tier's *operation* spans.
//!
//! Three pillars, three modules:
//!
//! * [`mod@doctor`] — a deep, read-only consistency audit that replays the
//!   Delta log and cross-checks every layer (object sizes, DTPQ footers
//!   and chunk bounds, FTSF chunk grids, index artifact geometry and
//!   row continuity, orphans) into a [`HealthReport`] with per-check
//!   severity and byte locations. CLI verb `doctor`; CI bin `tablecheck`.
//! * [`journal`] — a ring-buffered, typed event log of every commit-shaped
//!   operation (who landed what at which version, with retries, bytes and
//!   duration), exported as JSONL and rendered by `history --journal`.
//! * [`mod@probe`] — cheap per-table gauges (space amplification, delta
//!   fan-out, index staleness age, log-replay debt, cache heatmap) sampled
//!   in-loop by the workload harnesses so BENCH reports carry health
//!   trajectories.
//!
//! The last doctor/probe outcome parks in process-wide statics rendered by
//! [`report`] in the same `name value` tier format as the other engines,
//! so `stats` (and its Prometheus rendering) always shows the most recent
//! health picture without re-running anything.

pub mod doctor;
pub mod journal;
pub mod probe;

pub use doctor::{doctor, DoctorOptions, Finding, HealthReport, Severity};
pub use probe::{probe, ProbeReport};

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide health-tier counters and last-outcome gauges.
#[derive(Default)]
pub struct HealthStats {
    /// Doctor audits run.
    pub doctor_runs: AtomicU64,
    /// Warn-severity findings in the most recent audit.
    pub last_warn: AtomicU64,
    /// Corrupt-severity findings in the most recent audit.
    pub last_corrupt: AtomicU64,
    /// Probes run.
    pub probes: AtomicU64,
    /// Last probe's space amplification, in thousandths (1000 = 1.0x).
    pub space_amp_milli: AtomicU64,
    /// Last probe's live delta-segment count.
    pub delta_segments: AtomicU64,
    /// Last probe's stale-index count.
    pub stale_indexes: AtomicU64,
    /// Last probe's max index staleness age in versions.
    pub staleness_age: AtomicU64,
    /// Last probe's commits-since-checkpoint count.
    pub log_since_checkpoint: AtomicU64,
}

static STATS: once_cell::sync::Lazy<HealthStats> =
    once_cell::sync::Lazy::new(HealthStats::default);

/// Health-tier counters.
pub fn stats() -> &'static HealthStats {
    &STATS
}

/// Park a finished audit's finding counts for [`report`].
pub(crate) fn note_doctor(findings: &[Finding]) {
    STATS.doctor_runs.fetch_add(1, Ordering::Relaxed);
    let warn = findings.iter().filter(|f| f.severity == Severity::Warn).count() as u64;
    let corrupt = findings.iter().filter(|f| f.severity == Severity::Corrupt).count() as u64;
    STATS.last_warn.store(warn, Ordering::Relaxed);
    STATS.last_corrupt.store(corrupt, Ordering::Relaxed);
}

/// Park a finished probe's gauges for [`report`].
pub(crate) fn note_probe(r: &ProbeReport) {
    STATS.probes.fetch_add(1, Ordering::Relaxed);
    STATS.space_amp_milli.store((r.space_amp * 1000.0).round() as u64, Ordering::Relaxed);
    STATS.delta_segments.store(r.delta_segments, Ordering::Relaxed);
    STATS.stale_indexes.store(r.stale_indexes, Ordering::Relaxed);
    STATS.staleness_age.store(r.staleness_age, Ordering::Relaxed);
    STATS.log_since_checkpoint.store(r.log_since_checkpoint, Ordering::Relaxed);
}

/// Plain-text health-tier metrics report, in the same `name value` format
/// as the other engines' reports (rendered as Prometheus gauges by the
/// telemetry exporter).
pub fn report() -> String {
    format!(
        "health.doctor_runs {}\nhealth.doctor_warn {}\nhealth.doctor_corrupt {}\n\
         health.probes {}\nhealth.space_amp_milli {}\nhealth.delta_segments {}\n\
         health.stale_indexes {}\nhealth.staleness_age {}\n\
         health.log_since_checkpoint {}\n\
         health.journal_recorded {}\nhealth.journal_dropped {}\n",
        STATS.doctor_runs.load(Ordering::Relaxed),
        STATS.last_warn.load(Ordering::Relaxed),
        STATS.last_corrupt.load(Ordering::Relaxed),
        STATS.probes.load(Ordering::Relaxed),
        STATS.space_amp_milli.load(Ordering::Relaxed),
        STATS.delta_segments.load(Ordering::Relaxed),
        STATS.stale_indexes.load(Ordering::Relaxed),
        STATS.staleness_age.load(Ordering::Relaxed),
        STATS.log_since_checkpoint.load(Ordering::Relaxed),
        journal::recorded(),
        journal::dropped(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_lists_all_gauges() {
        let text = report();
        for name in [
            "health.doctor_runs",
            "health.doctor_warn",
            "health.doctor_corrupt",
            "health.probes",
            "health.space_amp_milli",
            "health.delta_segments",
            "health.stale_indexes",
            "health.staleness_age",
            "health.log_since_checkpoint",
            "health.journal_recorded",
            "health.journal_dropped",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        for line in text.lines() {
            let mut parts = line.split_whitespace();
            assert!(parts.next().is_some() && parts.next().is_some(), "bad line {line:?}");
        }
    }
}
