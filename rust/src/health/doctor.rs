//! The table doctor: a deep, read-only consistency audit.
//!
//! [`doctor`] replays the Delta log to a ground-truth snapshot (no cache)
//! and cross-checks every layer the log claims against what the object
//! store actually holds:
//!
//! * every live Add's object exists and is exactly the recorded size;
//! * every DTPQ part's footer parses, and every column chunk it describes
//!   lies inside the file ([`DoctorOptions::deep`] additionally fetches
//!   each chunk and verifies its crc32);
//! * FTSF tensors' chunk grids are complete — the live parts' chunk-index
//!   ranges tile `[0, n_chunks)` with no gap or overlap;
//! * index artifacts decode (magic, version, geometry), postings and
//!   codebooks are pinned and sized to the offset table, delta segments
//!   match the pinned geometry and their journaled row counts add up, and
//!   the build fingerprint still matches the live data files
//!   (via [`crate::index`]'s audit hook, so artifact formats stay private
//!   to the index tier);
//! * unreferenced objects under the table root are reported as
//!   vacuum-able orphans.
//!
//! Findings carry a severity ([`Severity::Warn`] for recoverable drift,
//! [`Severity::Corrupt`] for log/object disagreement) and, where one
//! exists, the byte range implicated. The report serializes to JSON for
//! the `tablecheck` CI bin and renders as text for the `doctor` CLI verb.

use crate::delta::DeltaTable;
use crate::jsonx::Json;
use crate::objectstore::ObjectStore;
use crate::Result;
use anyhow::{ensure, Context};

/// How bad one finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational; nothing wrong.
    Ok,
    /// Recoverable drift: vacuum-able orphans, a stale index.
    Warn,
    /// The log and the store disagree; reads through this state can fail
    /// or lie.
    Corrupt,
}

impl Severity {
    /// Lowercase wire name (`ok`/`warn`/`corrupt`).
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Ok => "ok",
            Severity::Warn => "warn",
            Severity::Corrupt => "corrupt",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "ok" => Some(Severity::Ok),
            "warn" => Some(Severity::Warn),
            "corrupt" => Some(Severity::Corrupt),
            _ => None,
        }
    }
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One failed (or noteworthy) check.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Severity of the finding.
    pub severity: Severity,
    /// Check identifier, dotted (`object.size`, `part.footer`,
    /// `index.delta`, `orphan.data`, ...).
    pub check: String,
    /// Table-relative object path the finding is about.
    pub path: String,
    /// Byte range `(offset, len)` implicated, when the check localizes one.
    pub location: Option<(u64, u64)>,
    /// Human explanation: expected vs found.
    pub detail: String,
}

impl Finding {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("severity", Json::from(self.severity.name())),
            ("check", Json::from(self.check.as_str())),
            ("path", Json::from(self.path.as_str())),
        ];
        if let Some((off, len)) = self.location {
            pairs.push(("offset", Json::from(off)));
            pairs.push(("len", Json::from(len)));
        }
        pairs.push(("detail", Json::from(self.detail.as_str())));
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let sev = j.get("severity").and_then(Json::as_str).context("finding severity")?;
        Ok(Self {
            severity: Severity::parse(sev).with_context(|| format!("bad severity {sev:?}"))?,
            check: j.get("check").and_then(Json::as_str).context("finding check")?.to_string(),
            path: j.get("path").and_then(Json::as_str).context("finding path")?.to_string(),
            location: match (
                j.get("offset").and_then(Json::as_u64),
                j.get("len").and_then(Json::as_u64),
            ) {
                (Some(o), Some(l)) => Some((o, l)),
                _ => None,
            },
            detail: j.get("detail").and_then(Json::as_str).unwrap_or("").to_string(),
        })
    }

    /// One-line human rendering.
    pub fn render(&self) -> String {
        let loc = match self.location {
            Some((off, len)) => format!(" @ bytes [{off}, {})", off + len),
            None => String::new(),
        };
        format!("{:>7}  {:<20} {}{}  — {}", self.severity, self.check, self.path, loc, self.detail)
    }
}

/// Knobs for one doctor run.
#[derive(Debug, Clone, Copy, Default)]
pub struct DoctorOptions {
    /// Also fetch every DTPQ column chunk and verify its crc32 (full data
    /// read; the default audit reads only footers and index headers).
    pub deep: bool,
}

/// What one doctor run found.
#[derive(Debug, Clone)]
pub struct HealthReport {
    /// Table root audited.
    pub table: String,
    /// Log version the audit replayed to.
    pub version: u64,
    /// Store instance the table lives on.
    pub instance: u64,
    /// Whether chunk payloads were crc-verified.
    pub deep: bool,
    /// Objects cross-checked against the store.
    pub objects: u64,
    /// Bytes whose integrity was vouched for (sizes, headers, footers;
    /// chunk payloads in deep mode).
    pub bytes: u64,
    /// Individual checks executed.
    pub checks: u64,
    /// Wall milliseconds the audit took.
    pub elapsed_ms: f64,
    /// Everything that wasn't clean.
    pub findings: Vec<Finding>,
}

impl HealthReport {
    /// Warn-severity finding count.
    pub fn warns(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warn).count()
    }

    /// Corrupt-severity finding count.
    pub fn corrupts(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Corrupt).count()
    }

    /// True when no finding rose above [`Severity::Ok`].
    pub fn is_healthy(&self) -> bool {
        self.findings.is_empty()
    }

    /// JSON document (the `HEALTH_*.json` artifact format).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("report", Json::from("doctor")),
            ("table", Json::from(self.table.as_str())),
            ("version", Json::from(self.version)),
            ("instance", Json::from(self.instance)),
            ("deep", Json::from(self.deep)),
            ("objects", Json::from(self.objects)),
            ("bytes", Json::from(self.bytes)),
            ("checks", Json::from(self.checks)),
            ("elapsed_ms", Json::Float(self.elapsed_ms)),
            ("warn", Json::from(self.warns())),
            ("corrupt", Json::from(self.corrupts())),
            ("findings", Json::Arr(self.findings.iter().map(Finding::to_json).collect())),
        ])
    }

    /// Parse a `HEALTH_*.json` document back (the `tablecheck` bin).
    pub fn from_json(j: &Json) -> Result<Self> {
        ensure!(
            j.get("report").and_then(Json::as_str) == Some("doctor"),
            "not a doctor report (missing report=doctor)"
        );
        let findings = j
            .get("findings")
            .and_then(Json::as_arr)
            .context("findings missing")?
            .iter()
            .map(Finding::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            table: j.get("table").and_then(Json::as_str).context("table")?.to_string(),
            version: j.get("version").and_then(Json::as_u64).context("version")?,
            instance: j.get("instance").and_then(Json::as_u64).unwrap_or(0),
            deep: j.get("deep").and_then(Json::as_bool).unwrap_or(false),
            objects: j.get("objects").and_then(Json::as_u64).unwrap_or(0),
            bytes: j.get("bytes").and_then(Json::as_u64).unwrap_or(0),
            checks: j.get("checks").and_then(Json::as_u64).unwrap_or(0),
            elapsed_ms: j.get("elapsed_ms").and_then(Json::as_f64).unwrap_or(0.0),
            findings,
        })
    }

    /// Multi-line human rendering (the `doctor` CLI output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "doctor: {} @ v{} — {} objects, {} bytes vouched, {} checks in {:.1} ms\n",
            self.table, self.version, self.objects, self.bytes, self.checks, self.elapsed_ms
        );
        if self.findings.is_empty() {
            out.push_str("  healthy: zero findings\n");
        } else {
            out.push_str(&format!(
                "  {} finding(s): {} corrupt, {} warn\n",
                self.findings.len(),
                self.corrupts(),
                self.warns()
            ));
            for f in &self.findings {
                out.push_str("  ");
                out.push_str(&f.render());
                out.push('\n');
            }
        }
        out
    }
}

/// Run the audit against the table's latest version.
pub fn doctor(table: &DeltaTable, opts: &DoctorOptions) -> Result<HealthReport> {
    let started = std::time::Instant::now();
    // Ground truth: replay the log directly rather than trusting the
    // engine's snapshot cache — the doctor is what you run when caches
    // might be lying.
    let snap = table.snapshot()?;
    let store = table.store();
    let mut findings = Vec::new();
    let mut objects = 0u64;
    let mut bytes = 0u64;
    let mut checks = 0u64;

    // -- Pillar 1: every live Add vs the object it names. --------------
    for add in snap.files() {
        let key = table.data_key(&add.path);
        checks += 1;
        let Some(size) = store.head(&key)? else {
            findings.push(Finding {
                severity: Severity::Corrupt,
                check: "object.missing".into(),
                path: add.path.clone(),
                location: None,
                detail: format!(
                    "log pins {} B at v{} but the object is gone",
                    add.size, snap.version
                ),
            });
            continue;
        };
        objects += 1;
        if size != add.size {
            let lo = size.min(add.size);
            findings.push(Finding {
                severity: Severity::Corrupt,
                check: "object.size".into(),
                path: add.path.clone(),
                location: Some((lo, size.max(add.size) - lo)),
                detail: format!("log pins {} B, object holds {size} B", add.size),
            });
            continue; // size lies ⇒ every offset below would too
        }
        bytes += 8; // the (size, existence) pair just vouched for
        if add.path.ends_with(".dtpq") {
            audit_dtpq(store, &key, add, size, opts, &mut findings, &mut bytes, &mut checks)?;
        }
    }

    // -- Pillar 2: FTSF chunk-grid completeness. ------------------------
    audit_ftsf_grids(&snap, &mut findings, &mut checks);

    // -- Pillar 3: index artifacts (formats stay private to the tier). --
    let (io, ib, ic) = crate::index::doctor_audit(table, &snap, &mut findings)?;
    objects += io;
    bytes += ib;
    checks += ic;

    // -- Pillar 4: orphans — vacuum-able debris under the root. ---------
    let prefix = format!("{}/", table.root());
    let log = table.log_prefix();
    for key in store.list(&prefix)? {
        if key.starts_with(&log) {
            continue;
        }
        checks += 1;
        let rel = key.strip_prefix(&prefix).unwrap_or(&key);
        if !snap.files.contains_key(rel) {
            let sz = store.head(&key)?.unwrap_or(0);
            let under_index = rel.starts_with("index/");
            findings.push(Finding {
                severity: Severity::Warn,
                check: if under_index { "orphan.index" } else { "orphan.data" }.into(),
                path: rel.to_string(),
                location: Some((0, sz)),
                detail: format!("{sz} B unreferenced at v{} (vacuum reclaims it)", snap.version),
            });
        }
    }

    crate::health::note_doctor(&findings);
    Ok(HealthReport {
        table: table.root().to_string(),
        version: snap.version,
        instance: store.instance_id(),
        deep: opts.deep,
        objects,
        bytes,
        checks,
        elapsed_ms: started.elapsed().as_secs_f64() * 1e3,
        findings,
    })
}

/// Footer + chunk-bounds (and, deep, chunk-crc) audit of one DTPQ part.
#[allow(clippy::too_many_arguments)]
fn audit_dtpq(
    store: &dyn ObjectStore,
    key: &str,
    add: &crate::delta::AddFile,
    size: u64,
    opts: &DoctorOptions,
    findings: &mut Vec<Finding>,
    bytes: &mut u64,
    checks: &mut u64,
) -> Result<()> {
    *checks += 1;
    let footer = match crate::columnar::read_footer(store, key) {
        Ok(f) => f,
        Err(e) => {
            // The footer machinery lives in the file's tail: length word +
            // trailing magic occupy the last 10 bytes.
            findings.push(Finding {
                severity: Severity::Corrupt,
                check: "part.footer".into(),
                path: add.path.clone(),
                location: Some((size.saturating_sub(10), size.min(10))),
                detail: format!("footer unreadable: {e:#}"),
            });
            return Ok(());
        }
    };
    *bytes += 10; // tail magic + length word verified by the parse
    for (gi, g) in footer.row_groups.iter().enumerate() {
        for (ci, c) in g.columns.iter().enumerate() {
            *checks += 1;
            if c.offset < 6 || c.offset + c.len > size {
                findings.push(Finding {
                    severity: Severity::Corrupt,
                    check: "part.chunk_bounds".into(),
                    path: add.path.clone(),
                    location: Some((c.offset, c.len)),
                    detail: format!(
                        "group {gi} col {ci} claims bytes [{}, {}) in a {size} B file",
                        c.offset,
                        c.offset + c.len
                    ),
                });
                continue;
            }
            if opts.deep {
                *checks += 1;
                let body = store.get_range(key, c.offset, c.len)?;
                if crc32fast::hash(&body) != c.crc32 {
                    findings.push(Finding {
                        severity: Severity::Corrupt,
                        check: "part.chunk_crc".into(),
                        path: add.path.clone(),
                        location: Some((c.offset, c.len)),
                        detail: format!("group {gi} col {ci}: crc32 mismatch"),
                    });
                } else {
                    *bytes += c.len;
                }
            }
        }
    }
    Ok(())
}

/// FTSF completeness: for every tensor whose Add metadata carries the FTSF
/// geometry (`shape` + `cdims`), the live parts' chunk-index ranges must
/// tile `[0, n_chunks)` exactly.
fn audit_ftsf_grids(
    snap: &crate::delta::Snapshot,
    findings: &mut Vec<Finding>,
    checks: &mut u64,
) {
    use std::collections::BTreeMap;
    // tensor id -> (expected chunk count, carrier path)
    let mut grids: BTreeMap<&str, (u64, &str)> = BTreeMap::new();
    for f in snap.files() {
        let Some(meta) = f.meta.as_deref() else { continue };
        let Ok(j) = crate::jsonx::parse(meta) else { continue };
        let (Some(shape), Some(cd)) = (
            j.get("shape").and_then(Json::to_int_vec),
            j.get("cdims").and_then(Json::as_u64),
        ) else {
            continue;
        };
        let cd = cd as usize;
        if cd >= shape.len() {
            continue; // geometry() rejects this; read path reports it
        }
        let lead = &shape[..shape.len() - cd];
        let n_chunks: u64 = lead.iter().map(|&d| d.max(0) as u64).product();
        grids.insert(f.tensor_id.as_str(), (n_chunks, f.path.as_str()));
    }
    for (id, (n_chunks, carrier)) in grids {
        *checks += 1;
        let mut ranges: Vec<(i64, i64)> = snap
            .files_for_tensor(id)
            .iter()
            .filter(|f| f.path.ends_with(".dtpq"))
            .filter_map(|f| Some((f.min_key?, f.max_key?)))
            .collect();
        ranges.sort_unstable();
        let mut next = 0i64;
        let mut problem = None;
        for &(lo, hi) in &ranges {
            if lo > next {
                problem = Some(format!("chunks [{next}, {lo}) missing"));
                break;
            }
            if lo < next {
                problem = Some(format!("chunks [{lo}, {next}) covered twice"));
                break;
            }
            next = hi + 1;
        }
        if problem.is_none() && next != n_chunks as i64 {
            problem = Some(format!("chunks [{next}, {n_chunks}) missing"));
        }
        if let Some(p) = problem {
            findings.push(Finding {
                severity: Severity::Corrupt,
                check: "ftsf.grid".into(),
                path: carrier.to_string(),
                location: None,
                detail: format!("tensor {id:?}: grid of {n_chunks} chunks incomplete — {p}"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_names() {
        assert!(Severity::Ok < Severity::Warn && Severity::Warn < Severity::Corrupt);
        assert_eq!(Severity::parse("corrupt"), Some(Severity::Corrupt));
        assert_eq!(Severity::parse("weird"), None);
        assert_eq!(Severity::Warn.name(), "warn");
    }

    #[test]
    fn report_json_roundtrip() {
        let r = HealthReport {
            table: "t".into(),
            version: 9,
            instance: 4,
            deep: true,
            objects: 12,
            bytes: 34_567,
            checks: 88,
            elapsed_ms: 2.25,
            findings: vec![
                Finding {
                    severity: Severity::Corrupt,
                    check: "object.size".into(),
                    path: "data/p.dtpq".into(),
                    location: Some((100, 28)),
                    detail: "log pins 128 B, object holds 100 B".into(),
                },
                Finding {
                    severity: Severity::Warn,
                    check: "orphan.data".into(),
                    path: "data/dead.dtpq".into(),
                    location: Some((0, 64)),
                    detail: "64 B unreferenced".into(),
                },
            ],
        };
        let text = r.to_json().dump();
        let back = HealthReport::from_json(&crate::jsonx::parse(&text).unwrap()).unwrap();
        assert_eq!(back.table, "t");
        assert_eq!(back.version, 9);
        assert!(back.deep);
        assert_eq!(back.findings.len(), 2);
        assert_eq!(back.corrupts(), 1);
        assert_eq!(back.warns(), 1);
        assert_eq!(back.findings[0].location, Some((100, 28)));
        assert!(!back.is_healthy());
        assert!(back.render().contains("object.size"));
    }
}
