//! Command-line interface (hand-rolled: clap is unavailable offline).
//!
//! ```text
//! delta-tensor <command> [flags]
//!
//! Commands:
//!   ingest     generate a workload and store it          (--workload, --layout, ...)
//!   append     append rows along a tensor's leading dim  (--id, --rows)
//!   read       read a whole tensor                       (--id)
//!   slice      read a first-dimension slice              (--id, --start, --end)
//!   inspect    per-tensor stats (incl. dtype/shape) and read plans
//!   history    table commit history (time travel log)
//!   optimize   compact files + fold/refresh the index    (--id)
//!   vacuum     delete unreferenced data objects
//!   index      ANN index over a stored vector matrix     (index build / index status)
//!   search     top-k nearest stored vectors              (--id, --query | --row)
//!   load       stream shuffled training batches          (--id | --populate N, --epochs)
//!   bench      load harnesses                  (bench serve|ingest|search|maintain|loader)
//!   trace      run ONE op force-traced, print its span tree (trace read|slice|search|append)
//!   stats      metrics registry + tier counters          (--format prometheus|json)
//!   doctor     read-only consistency audit               (--deep, --probe, --json PATH)
//! ```
//!
//! `bench serve` drives the coordinator with a closed-loop Zipfian hot-set
//! workload ([`crate::workload::serve`]) and prints throughput, latency
//! quantiles, and the serving-tier counters; `bench ingest` drives the
//! write engine with concurrent batch-committing writers
//! ([`crate::workload::ingest`]) and prints tensors/s, per-commit latency
//! quantiles, and the write-engine counters; `bench search` drives the
//! vector index tier with a closed-loop Zipfian query pool
//! ([`crate::workload::search`]) and prints QPS, latency quantiles,
//! recall@k and the index-tier counters. Every bench subcommand takes
//! `--seed N`, which fully determines its Zipf draws, generated tensors,
//! query vectors and k-means initialization — identical seeds reproduce
//! identical runs across machines. `--json PATH` additionally writes the
//! machine-readable report for any of them.

use crate::coordinator::{Coordinator, IngestJob};
use crate::delta::DeltaTable;
use crate::objectstore::{CostModel, ObjectStoreHandle};
use crate::tensor::Slice;
use crate::util::human_bytes;
use crate::workload;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::BTreeMap;

/// Parsed command line: command, optional subcommand, `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Command name.
    pub command: String,
    /// Optional subcommand (the first token after the command when it does
    /// not start with `--`, as in `bench serve`).
    pub subcommand: Option<String>,
    /// `--key value` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let subcommand = match it.peek() {
            Some(v) if !v.starts_with("--") => it.next(),
            _ => None,
        };
        let mut flags = BTreeMap::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {a:?}"))?
                .to_string();
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(), // boolean flag
            };
            flags.insert(key, value);
        }
        Ok(Args { command, subcommand, flags })
    }

    /// Required string flag.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.flags.get(key).map(|s| s.as_str()).with_context(|| format!("missing --{key}"))
    }

    /// Optional string flag with default.
    pub fn opt<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Optional usize flag with default.
    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    /// Optional f64 flag with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    /// Boolean flag presence.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Build the object store from flags (`--store mem|fs|sim-fs`, `--root`,
/// `--net paper|fast|free`).
pub fn store_from_args(args: &Args) -> Result<ObjectStoreHandle> {
    let cost = match args.opt("net", "free") {
        "paper" => CostModel::paper_1gbps(),
        "vpc" => CostModel::vpc_100gbps(),
        "fast" => CostModel::fast_sim(),
        "free" => CostModel::free(),
        other => bail!("unknown --net {other:?} (paper|vpc|fast|free)"),
    };
    let kind = args.opt("store", "fs");
    let root = args.opt("root", "/tmp/delta-tensor-store").to_string();
    Ok(match kind {
        "mem" => ObjectStoreHandle::sim_mem(cost),
        "fs" => ObjectStoreHandle::sim_fs(root, cost)?,
        other => bail!("unknown --store {other:?} (mem|fs)"),
    })
}

/// Execute a parsed command. Returns the text to print.
pub fn run(args: &Args) -> Result<String> {
    if let Some(sub) = &args.subcommand {
        // Only `bench`, `index` and `trace` (and `help`, which ignores it)
        // take a subcommand; anywhere else a positional token is a usage
        // error, not noise.
        if !matches!(args.command.as_str(), "bench" | "index" | "trace" | "help") {
            bail!("unexpected argument {sub:?} for command {:?}", args.command);
        }
    }
    match args.command.as_str() {
        "help" | "--help" | "-h" => Ok(HELP.to_string()),
        "ingest" => cmd_ingest(args),
        "append" => cmd_append(args),
        "read" => cmd_read(args, false),
        "slice" => cmd_read(args, true),
        "inspect" => cmd_inspect(args),
        "history" => cmd_history(args),
        "optimize" => cmd_optimize(args),
        "vacuum" => cmd_vacuum(args),
        "index" => cmd_index(args),
        "search" => cmd_search(args),
        "load" => cmd_load(args),
        "bench" => cmd_bench(args),
        "trace" => cmd_trace(args),
        "stats" => cmd_stats(args),
        "doctor" => cmd_doctor(args),
        "metrics-demo" => cmd_metrics_demo(args),
        other => bail!("unknown command {other:?}; try `delta-tensor help`"),
    }
}

const HELP: &str = r#"delta-tensor — tensor storage on a Delta-Lake-style lakehouse

USAGE: delta-tensor <command> [--flag value ...]

COMMANDS
  ingest    --workload ffhq|uber|generic --layout auto|Binary|FTSF|COO|CSR|CSC|CSF|BSGS
            [--id NAME] [--seed N] [--scale tiny|default] [--workers N]
  append    --id NAME --rows N [--seed N]   append synthetic rows along the
            leading dimension of a stored FTSF f32 matrix; data, grown shape
            metadata and (when a fresh index covers it) the delta posting
            segment land in ONE atomic commit
  read      --id NAME            read a whole tensor, print a summary
  slice     --id NAME --start A --end B    read X[A:B, ...]
  inspect                        per-tensor stats (dtype, shape) and read plans
  history                        commit log (version, operation, timestamp)
            [--journal [--json]]  render this process's structured event
            journal (op, adds/removes, bytes, retries, duration, outcome)
            instead; --json emits JSONL
  optimize  --id NAME            compact a tensor's part files (chunk rank
                                 preserved) and fold/refresh its index
  vacuum                         delete unreferenced data objects
  index build                    build the IVF ANN index over a 2-D f32/f64 tensor
            [--id NAME] [--k N] [--iters N] [--sample N] [--nprobe N] [--seed N]
            [--pq] [--pq-m M]    (--pq: product-quantized postings — M subspaces
            of 1-byte codes per vector, exact re-rank at query time; --pq-m 0
            picks dim/4. --id omitted: picks the single indexable matrix, else
            lists them)
  index status --id NAME [--version V]    index freshness (fresh/STALE/missing;
            stale output distinguishes rewritten-in-place from changed data;
            PQ indexes also report codebook params + posting compression)
  search    --id NAME (--query V1,V2,... | --row N) [--k N] [--nprobe N]
            [--rerank N]         (--rerank: exact re-rank depth on a PQ index;
            0 = max(4k, 32), or the DT_RERANK env var when set)
  load      stream shuffled training batches from a stored 2-D+ tensor
            (--id NAME | --populate N [--dim D])  [--batch N] [--epochs N]
            [--seed N] [--depth N] [--gap N] [--checkpoint-at N]
            (seeded epoch shuffle + coalesced slice reads + prefetch;
            --populate writes a demo f32 corpus first; --checkpoint-at N
            stops epoch 0 after N batches, then resumes from the
            checkpoint to demonstrate mid-epoch recovery;
            DT_PREFETCH_MB bounds decoded prefetch bytes, default 64)
  bench serve                    closed-loop Zipfian serving load harness
            [--clients N] [--requests N] [--tensors N] [--dim0 N]
            [--zipf S] [--no-cache] [--warmup-off] [--layout NAME]
            [--seed N] [--workers N] [--json PATH]
            [--probe-every N]    sample the health gauges every N
            iterations of client 0 (trajectory lands in the report)
  bench ingest                   closed-loop batched-write load harness
            [--writers N] [--batches N] [--batch N] [--dim0 N]
            [--density F] [--layout NAME] [--seed N] [--json PATH]
  bench search                   closed-loop Zipfian vector-search harness
            [--clients N] [--queries N] [--rows N] [--dim N] [--clusters N]
            [--pool N] [--k N] [--nprobe N] [--zipf S] [--no-cache]
            [--warmup-off] [--pq] [--pq-m M] [--rerank N] [--seed N]
            [--json PATH]
  bench maintain                 closed-loop append/search/optimize harness
            [--clients N] [--queries N] [--rounds N] [--append N]
            [--optimize-every N] [--rows N] [--dim N] [--clusters N]
            [--pool N] [--k N] [--nprobe N] [--zipf S] [--rebuild-control]
            [--no-cache] [--pq] [--pq-m M] [--seed N] [--json PATH]
  bench loader                   shuffled-epoch streaming harness: the
            prefetching DataLoader vs a naive per-sample sequential reader
            [--samples N] [--dim N] [--batch N] [--epochs N] [--depth N]
            [--gap N] [--seed N] [--json PATH]
  bench contend                  bursty multi-writer commit-contention
            harness: writer fleets spread across tables mixing appends,
            index rebuilds and folds; reports commit success rate, rebase
            rate, retries-per-commit and commit-path latency quantiles
            [--writers N] [--tables N] [--iters N] [--burst N] [--rows N]
            [--append N] [--dim N] [--clusters N] [--seed N] [--json PATH]
  trace read|slice|search|append  run ONE operation force-traced (ignores
            DT_TRACE) and print its span tree with per-span I/O attribution
            (GET/PUT batches, bytes, cache hits, commit retries); flags
            follow the underlying verb — --id, [--start/--end], [--row N]
            [--k N] [--nprobe N] [--rerank N], [--rows N] — plus
            [--json PATH] to also write a Chrome trace_event document
            (load in chrome://tracing or https://ui.perfetto.dev)
  stats     [--format prometheus|json] [--read ID]   metrics registry +
            tier counters; --read first serves one whole-tensor read so
            the registry has live values
  doctor    read-only consistency audit: replays the Delta log and
            cross-checks object sizes, DTPQ footers + chunk bounds, FTSF
            chunk grids, index artifact geometry/codebooks/row counts, and
            vacuum-able orphans; findings carry severity (warn/corrupt) and
            byte locations.  [--deep] also crc-verifies every chunk;
            [--probe] appends the cheap O(snapshot) health gauges;
            [--json PATH] writes the machine-readable HealthReport
COMMON FLAGS
  --table NAME                   table root (default: tensors)
  --store mem|fs                 backend (default fs)   --root PATH
  --net   free|fast|paper|vpc    simulated network cost model (default free)
  --seed N                       reproducibility seed for every bench subcommand
                                 (Zipf draws, generated data, queries, k-means)
TRACING (runtime-gated, compiled always-on)
  DT_TRACE=0                     disable tracing (`trace` still forces it)
  DT_SLOW_MS=N                   slow-op log threshold, ms (default 100)
  DT_TRACE_KEEP=N                trace ring-buffer capacity (default 64)
  bench serve --trace-every N    sample every Nth request per client (0 = off)
HEALTH (see `doctor` and `history --journal`)
  DT_JOURNAL_KEEP=N              event-journal ring capacity (default 256)
  DT_PROBE_TOPK=N                cache-heatmap entries per probe (default 8)
COMMIT ARBITRATION (see `bench contend`)
  DT_COMMIT_QUEUE=N              per-table in-process commit queue: max
                                 writers waiting behind the active one
                                 (default 64; 0 disables local serialization)
  DT_REBASE_MAX=N                conflict-free rebase rounds one commit may
                                 absorb before giving up (default 32)

Benches for the paper's figures: `cargo bench` (see EXPERIMENTS.md).
"#;

fn open_table(args: &Args) -> Result<DeltaTable> {
    open_table_named(args, "tensors")
}

fn open_table_named(args: &Args, default_table: &str) -> Result<DeltaTable> {
    let store = store_from_args(args)?;
    DeltaTable::create_or_open(store, args.opt("table", default_table))
}

fn cmd_ingest(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let seed = args.opt_usize("seed", 42)? as u64;
    let layout = args.opt("layout", "auto").to_string();
    let scale = args.opt("scale", "tiny");
    let data: crate::formats::TensorData = match args.req("workload")? {
        "ffhq" => {
            let p = if scale == "default" {
                workload::FfhqParams::default_scale()
            } else {
                workload::FfhqParams::tiny()
            };
            workload::ffhq_like(seed, p).into()
        }
        "uber" => {
            let p = if scale == "default" {
                workload::UberParams::default_scale()
            } else {
                workload::UberParams::tiny()
            };
            workload::uber_like(seed, p).into()
        }
        "generic" => workload::generic_sparse(seed, &[64, 32, 32], 0.01)?.into(),
        other => bail!("unknown --workload {other:?}"),
    };
    let id = args
        .opt("id", "")
        .to_string();
    let id = if id.is_empty() {
        crate::formats::new_tensor_id(&layout.to_lowercase(), data.shape().len())
    } else {
        id
    };
    let workers = args.opt_usize("workers", 4)?;
    let c = Coordinator::new(table, workers, 8);
    let shape = data.shape().to_vec();
    c.submit(IngestJob { id: id.clone(), layout, data });
    let errors = c.drain();
    if !errors.is_empty() {
        bail!("ingest failed: {errors:?}");
    }
    let bytes = crate::formats::storage_bytes(c.table(), &id)?;
    Ok(format!(
        "stored {id} shape {shape:?} as {} ({})\n{}",
        crate::coordinator::discover_layout(c.table(), &id)?,
        human_bytes(bytes),
        c.report()
    ))
}

/// `append`: land synthetic rows along a stored FTSF f32 matrix's leading
/// dimension through the maintenance-aware append path — one atomic commit
/// carries the data, the grown shape metadata and (when a fresh index
/// covers the tensor) the delta posting segment. Rows come from the same
/// Gaussian-mixture generator the index benches use, at the tensor's
/// stored dimensionality.
fn cmd_append(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let id = args.req("id")?.to_string();
    let rows = args.opt_usize("rows", 64)?;
    let seed = args.opt_usize("seed", 42)? as u64;
    let stats = crate::query::table_stats(&table)?;
    let info = stats
        .iter()
        .find(|t| t.id == id)
        .with_context(|| format!("tensor {id:?} not found; see `inspect`"))?;
    if info.shape.len() != 2 || info.dtype != "f32" {
        bail!(
            "append generates f32 vector rows; tensor {id:?} is {} {:?} — \
             store a 2-D f32 matrix (e.g. via `bench search`/`bench maintain`)",
            info.dtype,
            info.shape
        );
    }
    let dim = info.shape[1];
    let data = workload::embedding_like(seed, rows, dim, 16, 0.05);
    let c = Coordinator::new(table, 1, 1);
    let v = c.append(&id, &data.into())?;
    let status = crate::index::status(c.table(), &id)?;
    Ok(format!("appended {rows} rows to {id} @ v{v} (index: {status})\n{}", c.report()))
}

fn cmd_read(args: &Args, sliced: bool) -> Result<String> {
    let table = open_table(args)?;
    let id = args.req("id")?;
    let slice = if sliced {
        let start = args.opt_usize("start", 0)?;
        let end = args.opt_usize("end", start + 1)?;
        Some(Slice::dim0(start, end))
    } else {
        None
    };
    let plan = crate::query::plan(&table, id, slice.as_ref())?;
    let sw = crate::util::Stopwatch::start();
    let data = crate::query::execute(&table, id, slice.as_ref())?;
    let secs = sw.secs();
    Ok(format!(
        "tensor {id} layout={} shape={:?} density={:.4}\nplan: {}/{} files, {} selected\nread in {:.3}s",
        plan.layout,
        data.shape(),
        data.density(),
        plan.selected_files,
        plan.total_files,
        human_bytes(plan.selected_bytes),
        secs
    ))
}

fn cmd_inspect(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let stats = crate::query::table_stats(&table)?;
    let snap = table.snapshot()?;
    let mut out = format!(
        "table {} @ v{} — {} files, {}\n",
        table.root(),
        snap.version,
        snap.files.len(),
        human_bytes(snap.total_bytes())
    );
    for t in stats {
        let shape = if t.shape.is_empty() {
            "?".to_string()
        } else {
            format!("{:?}", t.shape)
        };
        out.push_str(&format!(
            "  {:<28} {:<7} {:<4} files={:<4} rows={:<8} shape={:<20} {}{}\n",
            t.id,
            t.layout,
            t.dtype,
            t.files,
            t.rows,
            shape,
            human_bytes(t.bytes),
            if crate::index::is_indexable(&t.shape, &t.dtype) { "  [indexable]" } else { "" }
        ));
    }
    Ok(out)
}

fn cmd_history(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    if args.has("journal") {
        // The structured event journal: this process's commit-shaped
        // operations against this table, not the persisted log. Filter by
        // table root only — every CLI invocation opens a fresh store
        // handle, so instance ids differ between the op and the query.
        let events = crate::health::journal::events(None, Some(table.root()));
        if args.has("json") {
            return Ok(crate::health::journal::to_jsonl(&events));
        }
        if events.is_empty() {
            return Ok("journal empty (events are in-process; run an operation first)\n".into());
        }
        let mut out = String::new();
        for e in &events {
            out.push_str(&e.render());
            out.push('\n');
        }
        return Ok(out);
    }
    let mut out = String::new();
    for (v, op, ts) in table.history()? {
        out.push_str(&format!("v{v:<6} {op:<16} ts={ts}\n"));
    }
    Ok(out)
}

/// The `doctor` verb: run the read-only table audit, optionally deep
/// (crc-verify every chunk) and with the cheap probe gauges appended.
fn cmd_doctor(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let opts = crate::health::DoctorOptions { deep: args.has("deep") };
    let report = crate::health::doctor(&table, &opts)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, format!("{}\n", report.to_json().dump()))
            .with_context(|| format!("writing {path}"))?;
    }
    let mut out = report.render();
    if args.has("probe") {
        out.push_str(&crate::health::probe(&table)?.render());
    }
    Ok(out)
}

fn cmd_optimize(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let id = args.req("id")?;
    let c = Coordinator::new(table, 1, 1);
    let before = crate::formats::storage_bytes(c.table(), id)?;
    c.optimize(id)?;
    let after = crate::formats::storage_bytes(c.table(), id)?;
    Ok(format!("optimized {id}: {} -> {}", human_bytes(before), human_bytes(after)))
}

fn cmd_vacuum(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let n = table.vacuum()?;
    Ok(format!("vacuum removed {n} objects"))
}

fn cmd_bench(args: &Args) -> Result<String> {
    let what = args
        .subcommand
        .clone()
        .unwrap_or_else(|| args.opt("experiment", "serve").to_string());
    match what.as_str() {
        "serve" => cmd_bench_serve(args),
        "ingest" => cmd_bench_ingest(args),
        "search" => cmd_bench_search(args),
        "maintain" => cmd_bench_maintain(args),
        "loader" => cmd_bench_loader(args),
        "contend" => cmd_bench_contend(args),
        other => {
            bail!(
                "unknown bench {other:?} (try `bench serve`, `bench ingest`, `bench search`, \
                 `bench maintain`, `bench loader` or `bench contend`; figure benches run via \
                 `cargo bench`)"
            )
        }
    }
}

/// `index build` / `index status`: the CLI surface of the vector index
/// tier. `index build` with no `--id` discovers the table's indexable
/// matrices (2-D f32/f64, from the same per-tensor stats `inspect` prints)
/// and builds the single candidate, or lists them when ambiguous.
fn cmd_index(args: &Args) -> Result<String> {
    match args.subcommand.as_deref().unwrap_or("build") {
        "build" => cmd_index_build(args),
        "status" => cmd_index_status(args),
        other => bail!("unknown index subcommand {other:?} (try `index build` or `index status`)"),
    }
}

fn cmd_index_build(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let id = match args.flags.get("id") {
        Some(id) => id.clone(),
        None => {
            let cands: Vec<crate::query::TensorInfo> = crate::query::table_stats(&table)?
                .into_iter()
                .filter(|t| crate::index::is_indexable(&t.shape, &t.dtype))
                .collect();
            match cands.len() {
                1 => cands[0].id.clone(),
                0 => bail!(
                    "no indexable vector matrices (2-D f32/f64) in table {}; see `inspect`",
                    table.root()
                ),
                _ => bail!(
                    "multiple indexable tensors — pick one with --id: {}",
                    cands.iter().map(|t| t.id.as_str()).collect::<Vec<_>>().join(", ")
                ),
            }
        }
    };
    let d = crate::index::BuildParams::default();
    let p = crate::index::BuildParams {
        k: args.opt_usize("k", d.k)?,
        iters: args.opt_usize("iters", d.iters)?,
        sample: args.opt_usize("sample", d.sample)?,
        nprobe: args.opt_usize("nprobe", d.nprobe)?,
        seed: args.opt_usize("seed", d.seed as usize)? as u64,
        pq: args.has("pq"),
        pq_m: args.opt_usize("pq-m", d.pq_m)?,
    };
    let summary = crate::index::build(&table, &id, &p)?;
    Ok(format!("{}\n{}", summary.summary(), crate::index::report()))
}

fn cmd_index_status(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let id = args.req("id")?;
    if args.has("version") {
        let status = crate::index::status_at(&table, id, args.opt_usize("version", 0)? as u64)?;
        Ok(format!("index for {id}: {status}\n"))
    } else {
        // The latest-version report distinguishes a rewrite-in-place
        // (cheap fold refresh) from changed data (full rebuild).
        crate::index::status_report(&table, id)
    }
}

/// `search`: top-k nearest stored vectors to a query, through the IVF
/// index. The query comes from `--query v1,v2,...` or `--row N` (reuse a
/// stored vector — handy for "more like this" checks).
fn cmd_search(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let id = args.req("id")?;
    let ivf = crate::index::IvfIndex::open(&table, id)?;
    let query: Vec<f32> = if let Some(csv) = args.flags.get("query") {
        csv.split(',')
            .map(|s| {
                s.trim()
                    .parse::<f32>()
                    .with_context(|| format!("--query element {s:?} is not a number"))
            })
            .collect::<Result<Vec<f32>>>()?
    } else if args.has("row") {
        // A sliced read fetches just the requested row chunk — the whole
        // matrix never rides the wire for a "more like this" query.
        crate::index::load_row(&table, id, args.opt_usize("row", 0)?)?
    } else {
        bail!("search needs --query v1,v2,... or --row N");
    };
    let k = args.opt_usize("k", 10)?;
    let nprobe = args.opt_usize("nprobe", 0)?;
    let rerank = args.opt_usize("rerank", 0)?;
    let sw = crate::util::Stopwatch::start();
    let hits = ivf.search_with(&query, k, nprobe, rerank)?;
    let secs = sw.secs();
    let pq_note = match ivf.pq_params() {
        Some((m, ksub)) => {
            format!(", pq m={m} ksub={ksub} rerank {}", ivf.effective_rerank(k, rerank))
        }
        None => String::new(),
    };
    let mut out = format!(
        "index for {id}: {} — {} centroids over {} vectors (dim {}{pq_note})\n",
        ivf.status(),
        ivf.k,
        ivf.rows,
        ivf.dim
    );
    for (rank, n) in hits.iter().enumerate() {
        out.push_str(&format!("  #{rank:<3} row {:<8} dist {:.6}\n", n.row, n.dist));
    }
    out.push_str(&format!("searched in {:.3}ms\n", secs * 1e3));
    Ok(out)
}

/// `load`: stream shuffled training batches from a stored 2-D+ tensor
/// through the loader tier and print the achieved samples/s. With
/// `--populate N` a demo `[N, dim]` f32 corpus is written first (so the
/// verb is self-contained on a fresh store); with `--checkpoint-at N`
/// epoch 0 stops after N batches and resumes from the checkpoint — the
/// mid-epoch recovery path a restarted training job takes.
fn cmd_load(args: &Args) -> Result<String> {
    let table = open_table_named(args, "loader-bench")?;
    let c = Coordinator::new(table, args.opt_usize("workers", 4)?, 32);
    let id = if args.has("populate") {
        let p = workload::loader::LoaderParams {
            samples: args.opt_usize("populate", 256)?,
            dim: args.opt_usize("dim", 64)?,
            batch_size: args.opt_usize("batch", 32)?,
            seed: args.opt_usize("seed", 7)? as u64,
            ..workload::loader::LoaderParams::tiny()
        };
        workload::loader::populate_loader_corpus(&c, &p)?
    } else {
        args.req("id")?.to_string()
    };
    let opts = crate::loader::LoaderOptions {
        batch_size: args.opt_usize("batch", 32)?,
        seed: args.opt_usize("seed", 7)? as u64,
        depth: args.opt_usize("depth", 2)?,
        prefetch_bytes: None,
        coalesce_gap: args.opt_usize("gap", 8)?,
    };
    let loader = c.loader(&id, opts)?;
    let epochs = args.opt_usize("epochs", 1)?.max(1);
    let stop_at = args.opt_usize("checkpoint-at", 0)?;
    let sw = crate::util::Stopwatch::start();
    let (mut batches, mut samples) = (0u64, 0u64);
    let mut resumed = String::new();
    for e in 0..epochs {
        let mut it = loader.epoch(e as u64)?;
        if e == 0 && stop_at > 0 {
            // Demonstrate mid-epoch recovery: stop, checkpoint, resume.
            for _ in 0..stop_at {
                let Some(b) = it.next_batch()? else { break };
                batches += 1;
                samples += b.rows.len() as u64;
            }
            let ckpt = it.checkpoint();
            resumed = format!(
                "checkpointed epoch {} at cursor {} and resumed\n",
                ckpt.epoch, ckpt.cursor
            );
            it = loader.resume(ckpt)?;
        }
        while let Some(b) = it.next_batch()? {
            batches += 1;
            samples += b.rows.len() as u64;
        }
    }
    let secs = sw.secs();
    Ok(format!(
        "streamed {epochs} epoch(s) of {id} ({} samples x {:?}): {batches} batches, \
         {samples} samples in {secs:.3}s -> {:.0} samples/s\n{resumed}{}",
        loader.n_samples(),
        loader.sample_shape(),
        samples as f64 / secs.max(1e-9),
        c.report()
    ))
}

fn cmd_bench_loader(args: &Args) -> Result<String> {
    let table = open_table_named(args, "loader-bench")?;
    let c = Coordinator::new(table, args.opt_usize("workers", 4)?, 32);
    let d = workload::loader::LoaderParams::tiny();
    let params = workload::loader::LoaderParams {
        samples: args.opt_usize("samples", d.samples)?,
        dim: args.opt_usize("dim", d.dim)?,
        batch_size: args.opt_usize("batch", d.batch_size)?,
        epochs: args.opt_usize("epochs", d.epochs)?,
        depth: args.opt_usize("depth", d.depth)?,
        coalesce_gap: args.opt_usize("gap", d.coalesce_gap)?,
        prefetch_bytes: None,
        seed: args.opt_usize("seed", d.seed as usize)? as u64,
    };
    let report = workload::loader::run_loader_bench(&c, &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing loader report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), c.report()))
}

fn cmd_bench_search(args: &Args) -> Result<String> {
    let table = open_table_named(args, "search-bench")?;
    let params = workload::search::SearchParams {
        clients: args.opt_usize("clients", 4)?,
        queries_per_client: args.opt_usize("queries", 50)?,
        rows: args.opt_usize("rows", 2000)?,
        dim: args.opt_usize("dim", 32)?,
        clusters: args.opt_usize("clusters", 32)?,
        query_pool: args.opt_usize("pool", 16)?,
        k: args.opt_usize("k", 10)?,
        nprobe: args.opt_usize("nprobe", 0)?,
        zipf_s: args.opt_f64("zipf", 1.1)?,
        cache: !args.has("no-cache"),
        warmup: !args.has("warmup-off"),
        seed: args.opt_usize("seed", 7)? as u64,
        pq: args.has("pq"),
        pq_m: args.opt_usize("pq-m", 0)?,
        rerank: args.opt_usize("rerank", 0)?,
    };
    workload::search::populate_search_corpus(&table, "vectors", &params)?;
    let report = workload::search::run_search(&table, "vectors", &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing search report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), crate::index::report()))
}

fn cmd_bench_maintain(args: &Args) -> Result<String> {
    let table = open_table_named(args, "maintain-bench")?;
    let params = workload::maintain::MaintainParams {
        clients: args.opt_usize("clients", 4)?,
        queries_per_client: args.opt_usize("queries", 25)?,
        rounds: args.opt_usize("rounds", 3)?,
        append_rows: args.opt_usize("append", 64)?,
        optimize_every: args.opt_usize("optimize-every", 2)?,
        rows: args.opt_usize("rows", 2000)?,
        dim: args.opt_usize("dim", 32)?,
        clusters: args.opt_usize("clusters", 32)?,
        query_pool: args.opt_usize("pool", 16)?,
        k: args.opt_usize("k", 10)?,
        nprobe: args.opt_usize("nprobe", 0)?,
        zipf_s: args.opt_f64("zipf", 1.1)?,
        incremental: !args.has("rebuild-control"),
        cache: !args.has("no-cache"),
        seed: args.opt_usize("seed", 7)? as u64,
        pq: args.has("pq"),
        pq_m: args.opt_usize("pq-m", 0)?,
    };
    workload::maintain::populate_maintain_corpus(&table, "vectors", &params)?;
    let report = workload::maintain::run_maintain(&table, "vectors", &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing maintain report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), crate::index::report()))
}

fn cmd_bench_ingest(args: &Args) -> Result<String> {
    let table = open_table_named(args, "ingest-bench")?;
    let params = workload::ingest::IngestParams {
        writers: args.opt_usize("writers", 2)?,
        batches_per_writer: args.opt_usize("batches", 2)?,
        tensors_per_batch: args.opt_usize("batch", 8)?,
        dim0: args.opt_usize("dim0", 12)?,
        density: args.opt_f64("density", 0.05)?,
        layout: args.opt("layout", "COO").to_string(),
        seed: args.opt_usize("seed", 7)? as u64,
    };
    let report = workload::ingest::run_ingest(&table, &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing ingest report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), crate::ingest::report()))
}

fn cmd_bench_contend(args: &Args) -> Result<String> {
    let store = store_from_args(args)?;
    let params = workload::contend::ContendParams {
        writers: args.opt_usize("writers", 4)?,
        tables: args.opt_usize("tables", 2)?,
        iters_per_writer: args.opt_usize("iters", 4)?,
        burst_every: args.opt_usize("burst", 2)?,
        rows: args.opt_usize("rows", 256)?,
        append_rows: args.opt_usize("append", 16)?,
        dim: args.opt_usize("dim", 8)?,
        clusters: args.opt_usize("clusters", 4)?,
        seed: args.opt_usize("seed", 7)? as u64,
    };
    let tables = workload::contend::populate_contend(&store, &params)?;
    let report = workload::contend::run_contend(&tables, &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing contend report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), crate::ingest::report()))
}

fn cmd_bench_serve(args: &Args) -> Result<String> {
    let table = open_table_named(args, "serve-bench")?;
    let params = workload::serve::ServeParams {
        clients: args.opt_usize("clients", 4)?,
        requests_per_client: args.opt_usize("requests", 50)?,
        tensors: args.opt_usize("tensors", 6)?,
        dim0: args.opt_usize("dim0", 16)?,
        zipf_s: args.opt_f64("zipf", 1.1)?,
        cache: !args.has("no-cache"),
        warmup: !args.has("warmup-off"),
        seed: args.opt_usize("seed", 7)? as u64,
        layout: args.opt("layout", "COO").to_string(),
        trace_every: args.opt_usize("trace-every", 8)?,
        probe_every: args.opt_usize("probe-every", 0)?,
    };
    let c = Coordinator::new(table, args.opt_usize("workers", 4)?, 32);
    let ids = workload::serve::populate_serve_table(&c, &params)?;
    let report = workload::serve::run_serve(&c, &ids, &params)?;
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing serve report to {path}"))?;
    }
    Ok(format!("{}\n{}", report.summary(), c.report()))
}

/// `trace <op>`: run ONE operation force-traced (ignoring the `DT_TRACE`
/// runtime flag) and print its span tree with per-span I/O attribution —
/// the single-operation lens the tier counters cannot provide. With
/// `--json PATH` the trace is also written as a Chrome `trace_event`
/// document loadable in `chrome://tracing` or Perfetto.
fn cmd_trace(args: &Args) -> Result<String> {
    use crate::telemetry::export;
    let op = args.subcommand.as_deref().unwrap_or("read");
    let table = open_table(args)?;
    let id = args.req("id")?.to_string();
    let (headline, trace) = match op {
        "read" => {
            let c = Coordinator::new(table, 2, 8);
            let (data, trace) = c.read_traced(&id)?;
            (format!("read {id}: shape {:?}", data.shape()), trace)
        }
        "slice" => {
            let start = args.opt_usize("start", 0)?;
            let end = args.opt_usize("end", start + 1)?;
            let c = Coordinator::new(table, 2, 8);
            let (data, trace) = c.read_slice_traced(&id, &Slice::dim0(start, end))?;
            (format!("slice {id}[{start}..{end}]: shape {:?}", data.shape()), trace)
        }
        "search" => {
            // Load the query row BEFORE the trace starts so the span tree
            // covers exactly the search (probe/scan/rerank), not the
            // query's own fetch.
            let row = args.opt_usize("row", 0)?;
            let k = args.opt_usize("k", 10)?;
            let query = crate::index::load_row(&table, &id, row)?;
            let t = crate::telemetry::Trace::start_forced("search");
            let ivf = crate::index::IvfIndex::open(&table.with_span(t.root()), &id)?;
            let hits = ivf.search_with(
                &query,
                k,
                args.opt_usize("nprobe", 0)?,
                args.opt_usize("rerank", 0)?,
            )?;
            let trace = t.finish().expect("forced trace always finishes");
            let best = hits.first().map(|n| n.row).unwrap_or(0);
            (format!("search {id} row {row}: {} hits, best row {best}", hits.len()), trace)
        }
        "append" => {
            let rows = args.opt_usize("rows", 16)?;
            let seed = args.opt_usize("seed", 42)? as u64;
            let stats = crate::query::table_stats(&table)?;
            let info = stats
                .iter()
                .find(|t| t.id == id)
                .with_context(|| format!("tensor {id:?} not found; see `inspect`"))?;
            ensure!(
                info.shape.len() == 2 && info.dtype == "f32",
                "trace append generates f32 vector rows; tensor {id:?} is {} {:?}",
                info.dtype,
                info.shape
            );
            let data = workload::embedding_like(seed, rows, info.shape[1], 16, 0.05);
            let c = Coordinator::new(table, 1, 1);
            let (v, trace) = c.append_traced(&id, &data.into())?;
            (format!("append {rows} rows to {id} @ v{v}"), trace)
        }
        other => bail!("unknown trace op {other:?} (try `trace read|slice|search|append`)"),
    };
    let mut out = format!("{headline}\n{}", export::render_tree(&trace));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, export::chrome_trace_json(&[trace]).dump())
            .with_context(|| format!("writing chrome trace to {path}"))?;
        out.push_str(&format!("wrote chrome trace_event JSON to {path} (load in Perfetto)\n"));
    }
    Ok(out)
}

/// `stats`: the coordinator's metrics registry plus every tier's counters,
/// rendered as Prometheus exposition text (default) or one JSON document.
/// `--read ID` first serves one whole-tensor read through the coordinator
/// so the registry has live counters/histograms to show.
fn cmd_stats(args: &Args) -> Result<String> {
    let table = open_table(args)?;
    let c = Coordinator::new(table, 2, 8);
    if let Some(id) = args.flags.get("read") {
        let _ = c.read(id)?;
    }
    let tiers = format!(
        "{}{}{}{}{}{}",
        crate::query::engine::report(),
        crate::serving::report(),
        crate::ingest::report(),
        crate::index::report(),
        crate::telemetry::report(),
        crate::health::report()
    );
    match args.opt("format", "prometheus") {
        "prometheus" => Ok(crate::telemetry::export::prometheus_text(c.metrics(), &tiers)),
        "json" => {
            let mut s = crate::telemetry::export::stats_json(c.metrics(), &tiers).dump();
            s.push('\n');
            Ok(s)
        }
        other => bail!("unknown --format {other:?} (prometheus|json)"),
    }
}

fn cmd_metrics_demo(args: &Args) -> Result<String> {
    // Small end-to-end smoke used by `make test` docs: write + read + report.
    let table = open_table(args)?;
    let c = Coordinator::new(table, 2, 4);
    let data = workload::generic_sparse(7, &[16, 8, 8], 0.05)?;
    c.submit(IngestJob { id: "demo".into(), layout: "BSGS".into(), data: data.into() });
    let errs = c.drain();
    if !errs.is_empty() {
        bail!("{errs:?}");
    }
    let _ = c.read_slice("demo", &Slice::index(3))?;
    Ok(c.report())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parse_flags() {
        let a = args(&["ingest", "--workload", "uber", "--layout", "CSF", "--dry-run"]);
        assert_eq!(a.command, "ingest");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.req("workload").unwrap(), "uber");
        assert_eq!(a.opt("layout", "auto"), "CSF");
        assert!(a.has("dry-run"));
        assert!(a.req("missing").is_err());
        // A stray positional after the flags start is still an error.
        let stray = ["x", "--k", "v", "stray"].iter().map(|s| s.to_string());
        assert!(Args::parse(stray).is_err());
    }

    #[test]
    fn parse_subcommand() {
        let a = args(&["bench", "serve", "--clients", "2"]);
        assert_eq!(a.command, "bench");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_usize("clients", 0).unwrap(), 2);
        assert_eq!(a.opt_f64("zipf", 1.1).unwrap(), 1.1);
    }

    #[test]
    fn help_and_unknown() {
        assert!(run(&args(&["help"])).unwrap().contains("USAGE"));
        assert!(run(&args(&["frobnicate"])).is_err());
        // Stray positionals are rejected for commands without subcommands.
        assert!(run(&args(&["vacuum", "stray", "--store", "mem"])).is_err());
        assert!(run(&args(&["help", "bench"])).is_ok());
    }

    #[test]
    fn end_to_end_ingest_read_inspect_mem() {
        let common = ["--store", "mem", "--table", "t"];
        // NOTE: mem stores don't persist between commands, so run the full
        // flow against one table via the library path instead; here we only
        // verify the ingest command text on a fresh in-memory store.
        let mut v = vec!["ingest", "--workload", "generic", "--layout", "COO", "--id", "g1"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("stored g1"), "{out}");
        assert!(out.contains("COO"), "{out}");
    }

    #[test]
    fn fs_store_full_flow() {
        let root = std::env::temp_dir().join(format!("dt-cli-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let rootflag = root.to_string_lossy().to_string();
        let common = ["--store", "fs", "--root", &rootflag, "--table", "t"];

        let mut v = vec!["ingest", "--workload", "uber", "--layout", "BSGS", "--id", "u1"];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        let mut v = vec!["read", "--id", "u1"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("layout=BSGS"), "{out}");

        let mut v = vec!["slice", "--id", "u1", "--start", "2", "--end", "4"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("shape=[2, 24, 32, 48]"), "{out}");

        let mut v = vec!["inspect"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("u1"), "{out}");

        let mut v = vec!["history"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("CREATE TABLE"), "{out}");

        let mut v = vec!["optimize", "--id", "u1"];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        let mut v = vec!["vacuum"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("vacuum removed"), "{out}");

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bench_serve_smoke() {
        let out = run(&args(&[
            "bench", "serve", "--store", "mem", "--clients", "2", "--requests", "5",
            "--tensors", "2", "--dim0", "4",
        ]))
        .unwrap();
        assert!(out.contains("req/s"), "{out}");
        assert!(out.contains("serving.cache_hits"), "{out}");
        assert!(run(&args(&["bench", "frobnicate"])).is_err());
    }

    #[test]
    fn bench_search_smoke() {
        let out = run(&args(&[
            "bench", "search", "--store", "mem", "--clients", "2", "--queries", "5",
            "--rows", "200", "--dim", "8", "--clusters", "4", "--pool", "4", "--seed", "3",
        ]))
        .unwrap();
        assert!(out.contains("q/s"), "{out}");
        assert!(out.contains("recall@10"), "{out}");
        assert!(out.contains("index.builds"), "{out}");
    }

    #[test]
    fn bench_search_pq_smoke() {
        let out = run(&args(&[
            "bench", "search", "--store", "mem", "--clients", "2", "--queries", "5",
            "--rows", "200", "--dim", "8", "--clusters", "4", "--pool", "4", "--seed", "3",
            "--pq",
        ]))
        .unwrap();
        assert!(out.contains("pq rerank"), "{out}");
        assert!(out.contains("recall@10"), "{out}");
        assert!(out.contains("index.reranked_rows"), "{out}");
    }

    #[test]
    fn index_and_search_fs_flow() {
        let root = std::env::temp_dir().join(format!("dt-cli-idx-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let rootflag = root.to_string_lossy().to_string();
        let common = ["--store", "fs", "--root", &rootflag, "--table", "sb"];

        // `bench search` populates a 2-D f32 corpus ("vectors") + its index.
        let mut v = vec![
            "bench", "search", "--clients", "1", "--queries", "3", "--rows", "150", "--dim",
            "8", "--clusters", "4", "--pool", "3", "--seed", "5",
        ];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        // The corpus is visible (and flagged indexable) in inspect.
        let mut v = vec!["inspect"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("vectors"), "{out}");
        assert!(out.contains("f32"), "{out}");
        assert!(out.contains("[indexable]"), "{out}");

        let mut v = vec!["index", "status", "--id", "vectors"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("fresh"), "{out}");

        // Appending rows keeps the index fresh: the delta posting segment
        // rides the same commit as the data.
        let mut v = vec!["append", "--id", "vectors", "--rows", "8", "--seed", "9"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("appended 8 rows"), "{out}");
        assert!(out.contains("index: fresh"), "{out}");

        // Searching with a stored row as the query returns that row first.
        let mut v = vec!["search", "--id", "vectors", "--row", "0", "--k", "3"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("#0   row 0"), "{out}");

        // Rebuild with --id picks the same tensor; auto-discovery agrees.
        let mut v = vec!["index", "build", "--seed", "6"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("built ivf index"), "{out}");

        // PQ rebuild: 1-byte codes in the postings, exact re-rank at query
        // time; status reports the codebook, search still puts row 0 first.
        let mut v = vec!["index", "build", "--seed", "6", "--pq", "--pq-m", "2"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("built ivf index"), "{out}");
        assert!(out.contains("pq"), "{out}");

        let mut v = vec!["index", "status", "--id", "vectors"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("pq codebook"), "{out}");

        let mut v =
            vec!["search", "--id", "vectors", "--row", "0", "--k", "3", "--rerank", "50"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("#0   row 0"), "{out}");
        assert!(out.contains("pq m=2"), "{out}");

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bench_maintain_smoke() {
        let out = run(&args(&[
            "bench", "maintain", "--store", "mem", "--clients", "2", "--queries", "4",
            "--rounds", "2", "--append", "16", "--rows", "300", "--dim", "8", "--clusters",
            "4", "--pool", "4", "--seed", "3",
        ]))
        .unwrap();
        assert!(out.contains("maintain (incremental)"), "{out}");
        assert!(out.contains("index.appends"), "{out}");
        assert!(out.contains("index.folds"), "{out}");
    }

    #[test]
    fn load_smoke() {
        // Self-contained on a fresh mem store: --populate writes the demo
        // corpus, --checkpoint-at exercises the mid-epoch resume path.
        let out = run(&args(&[
            "load", "--store", "mem", "--populate", "48", "--dim", "8", "--batch", "8",
            "--epochs", "2", "--checkpoint-at", "2", "--seed", "5",
        ]))
        .unwrap();
        assert!(out.contains("96 samples"), "{out}");
        assert!(out.contains("12 batches"), "{out}");
        assert!(out.contains("checkpointed epoch 0 at cursor 16"), "{out}");
        assert!(out.contains("loader.batches"), "{out}");
        // Without --populate the tensor must exist.
        assert!(run(&args(&["load", "--store", "mem", "--id", "nope"])).is_err());
    }

    #[test]
    fn bench_loader_smoke() {
        let out = run(&args(&[
            "bench", "loader", "--store", "mem", "--samples", "32", "--dim", "8", "--batch",
            "8", "--epochs", "2", "--seed", "3",
        ]))
        .unwrap();
        assert!(out.contains("samples/s"), "{out}");
        assert!(out.contains("naive"), "{out}");
        assert!(out.contains("loader is"), "{out}");
        assert!(out.contains("loader.samples"), "{out}");
    }

    #[test]
    fn bench_ingest_smoke() {
        let out = run(&args(&[
            "bench", "ingest", "--store", "mem", "--writers", "1", "--batches", "1",
            "--batch", "3", "--dim0", "6",
        ]))
        .unwrap();
        assert!(out.contains("tensors/s"), "{out}");
        assert!(out.contains("ingest.put_batches"), "{out}");
    }

    #[test]
    fn bench_contend_smoke() {
        let out = run(&args(&[
            "bench", "contend", "--store", "mem", "--writers", "2", "--tables", "2", "--iters",
            "2", "--rows", "96", "--append", "8", "--dim", "8", "--clusters", "3",
        ]))
        .unwrap();
        assert!(out.contains("commits/s"), "{out}");
        assert!(out.contains("success rate 1.0000"), "{out}");
        assert!(out.contains("ingest.commit_rebases"), "{out}");
    }

    #[test]
    fn trace_and_stats_fs_flow() {
        let root = std::env::temp_dir().join(format!("dt-cli-trace-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let rootflag = root.to_string_lossy().to_string();
        let common = ["--store", "fs", "--root", &rootflag, "--table", "t"];

        let mut v = vec!["ingest", "--workload", "generic", "--layout", "COO", "--id", "g1"];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        // `trace slice` prints the span tree and writes a structurally
        // valid Chrome trace_event document.
        let json_path = root.join("trace.json");
        let json_flag = json_path.to_string_lossy().to_string();
        let mut v = vec![
            "trace", "slice", "--id", "g1", "--start", "1", "--end", "3", "--json", &json_flag,
        ];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("TRACE read_slice"), "{out}");
        assert!(out.contains("fetch"), "{out}");
        assert!(out.contains("decode"), "{out}");
        let doc = crate::jsonx::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        crate::telemetry::export::validate_chrome_trace(&doc).unwrap();

        let mut v = vec!["trace", "read", "--id", "g1"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("TRACE read"), "{out}");

        let mut v = vec!["trace", "frobnicate", "--id", "g1"];
        v.extend_from_slice(&common);
        assert!(run(&args(&v)).is_err());

        // `stats` renders the registry + tier counters; --read gives the
        // per-coordinator registry live values.
        let mut v = vec!["stats", "--format", "prometheus", "--read", "g1"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("# TYPE delta_tensor_read_tensor counter"), "{out}");
        assert!(out.contains("delta_tensor_read_tensor 1"), "{out}");
        assert!(out.contains("delta_tensor_engine_part_fetches"), "{out}");
        assert!(out.contains("delta_tensor_telemetry_enabled"), "{out}");

        let mut v = vec!["stats", "--format", "json"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        let j = crate::jsonx::parse(&out).unwrap();
        assert!(j.get("tiers").is_some(), "{out}");
        assert!(j.get("counters").is_some(), "{out}");

        let mut v = vec!["stats", "--format", "xml"];
        v.extend_from_slice(&common);
        assert!(run(&args(&v)).is_err());

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn trace_search_fs_flow() {
        let root = std::env::temp_dir().join(format!("dt-cli-trsr-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let rootflag = root.to_string_lossy().to_string();
        let common = ["--store", "fs", "--root", &rootflag, "--table", "sb"];

        // `bench search` populates a 2-D f32 corpus ("vectors") + index.
        let mut v = vec![
            "bench", "search", "--clients", "1", "--queries", "2", "--rows", "150", "--dim",
            "8", "--clusters", "4", "--pool", "2", "--seed", "5",
        ];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        let mut v = vec!["trace", "search", "--id", "vectors", "--row", "0", "--k", "3"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("TRACE search"), "{out}");
        assert!(out.contains("probe"), "{out}");
        assert!(out.contains("scan"), "{out}");
        assert!(out.contains("best row 0"), "{out}");

        let mut v = vec!["trace", "append", "--id", "vectors", "--rows", "8", "--seed", "9"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("TRACE append"), "{out}");
        assert!(out.contains("commit"), "{out}");
        assert!(out.contains("append 8 rows"), "{out}");

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn doctor_and_journal_fs_flow() {
        let root = std::env::temp_dir().join(format!("dt-cli-doc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let rootflag = root.to_string_lossy().to_string();
        // Unique table name: the journal is process-global and other tests
        // in this binary also journal against tables named "t".
        let common = ["--store", "fs", "--root", &rootflag, "--table", "doc9"];

        let mut v = vec!["ingest", "--workload", "ffhq", "--layout", "FTSF", "--id", "g1"];
        v.extend_from_slice(&common);
        run(&args(&v)).unwrap();

        // A clean table audits clean, shallow and deep, and --json writes
        // a HealthReport document that parses back.
        let json_path = root.join("health.json");
        let json_flag = json_path.to_string_lossy().to_string();
        let mut v = vec!["doctor", "--deep", "--probe", "--json", &json_flag];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("healthy: zero findings"), "{out}");
        assert!(out.contains("probe:"), "{out}");
        let doc = crate::jsonx::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        let report = crate::health::HealthReport::from_json(&doc).unwrap();
        assert!(report.is_healthy(), "{:?}", report.findings);
        assert!(report.deep && report.objects > 0 && report.checks > 0);

        // The ingest commits journaled; `history --journal` renders them
        // and --json emits one JSON object per line.
        let mut v = vec!["history", "--journal"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("CREATE TABLE"), "{out}");
        assert!(out.contains("WRITE"), "{out}");
        let mut v = vec!["history", "--journal", "--json"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        for line in out.lines() {
            let j = crate::jsonx::parse(line).unwrap();
            assert_eq!(j.get("table").and_then(crate::jsonx::Json::as_str), Some("doc9"));
        }

        // `stats` now carries the health tier gauges.
        let mut v = vec!["stats"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("health_doctor_runs"), "{out}");

        // A truncated part file is detected as corrupt.
        let part = find_one_dtpq(&root.join("doc9"));
        let full = std::fs::read(&part).unwrap();
        std::fs::write(&part, &full[..full.len() - 4]).unwrap();
        let mut v = vec!["doctor"];
        v.extend_from_slice(&common);
        let out = run(&args(&v)).unwrap();
        assert!(out.contains("corrupt"), "{out}");
        assert!(out.contains("object.size"), "{out}");

        let _ = std::fs::remove_dir_all(&root);
    }

    /// First `.dtpq` object under a table's fs root (test helper).
    fn find_one_dtpq(dir: &std::path::Path) -> std::path::PathBuf {
        let mut stack = vec![dir.to_path_buf()];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap() {
                let p = e.unwrap().path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|x| x == "dtpq") {
                    return p;
                }
            }
        }
        panic!("no .dtpq under {dir:?}");
    }

    #[test]
    fn store_flags_validated() {
        assert!(store_from_args(&args(&["x", "--net", "warp"])).is_err());
        assert!(store_from_args(&args(&["x", "--store", "s3"])).is_err());
        assert!(store_from_args(&args(&["x", "--store", "mem", "--net", "fast"])).is_ok());
    }
}
