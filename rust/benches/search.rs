//! Vector-search load bench: the closed-loop Zipfian top-k workload of
//! `workload::search`, run twice over a fresh simulated cloud store — once
//! with posting fetches riding the serving tier's block cache, once
//! straight to the backend — and compared on QPS, latency quantiles,
//! recall@k, GETs and bytes moved.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_SEED` (workload seed, default 7), `DT_BENCH_OUT` (JSON report path,
//! default `BENCH_search.json`). CI runs the tiny scale and gates
//! `cache.throughput_qps` against `bench_baselines/search.json`.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::util::human_bytes;
use delta_tensor::workload::search::{
    populate_search_corpus, run_search, SearchParams, SearchReport,
};

fn run_once(cache: bool, params: &SearchParams) -> SearchReport {
    let mut params = params.clone();
    params.cache = cache;
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "search").expect("fresh table");
    populate_search_corpus(&table, "vectors", &params).expect("populate");
    run_search(&table, "vectors", &params).expect("search run")
}

fn main() {
    let mut params = match benchkit::scale() {
        Scale::Tiny => SearchParams::tiny(),
        Scale::Small => SearchParams::small(),
        Scale::Paper => SearchParams::paper(),
    };
    if let Ok(seed) = std::env::var("DT_SEED") {
        params.seed = seed.parse().expect("DT_SEED must be an integer");
    }
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for cache in [true, false] {
        let r = run_once(cache, &params);
        rows.push(Row {
            label: if cache { "cache" } else { "no-cache" }.to_string(),
            cells: vec![
                format!("{:.0}", r.throughput_qps),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p95_secs),
                fmt_secs(r.p99_secs),
                format!("{:.4}", r.recall_at_k),
                r.get_ops.to_string(),
                human_bytes(r.bytes_read),
            ],
        });
        reports.push(r);
    }
    print_table(
        "search: closed-loop Zipfian top-k queries, serving tier on vs off",
        &["mode", "q/s", "p50", "p95", "p99", "recall@k", "GETs", "bytes"],
        &rows,
    );
    let speedup = reports[0].throughput_qps / reports[1].throughput_qps.max(1e-9);
    println!("\nthroughput speedup with serving tier: {speedup:.2}x");

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".to_string());
    let json = format!(
        "{{\"bench\":\"search\",\"cache\":{},\"no_cache\":{},\"speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");
}
