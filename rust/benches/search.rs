//! Vector-search load bench: the closed-loop Zipfian top-k workload of
//! `workload::search`, run over a fresh simulated cloud store in four
//! configurations — Flat and PQ postings, each with posting fetches riding
//! the serving tier's block cache and straight to the backend — and
//! compared on QPS, latency quantiles, recall@k, GETs, bytes moved and
//! posting bytes fetched (the I/O PQ compresses).
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_SEED` (workload seed, default 7), `DT_BENCH_OUT` (Flat JSON report
//! path, default `BENCH_search.json`), `DT_BENCH_OUT_PQ` (PQ JSON report
//! path, default `BENCH_search_pq.json`). CI runs the tiny scale and gates
//! both reports against `bench_baselines/search.json` and
//! `bench_baselines/search_pq.json`.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::util::human_bytes;
use delta_tensor::workload::search::{
    populate_search_corpus, run_search, SearchParams, SearchReport,
};

fn run_once(cache: bool, params: &SearchParams) -> SearchReport {
    let mut params = params.clone();
    params.cache = cache;
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "search").expect("fresh table");
    populate_search_corpus(&table, "vectors", &params).expect("populate");
    run_search(&table, "vectors", &params).expect("search run")
}

/// Run the cache-on / cache-off pair for one posting encoding, appending a
/// table row per run.
fn bench_pair(params: &SearchParams, tag: &str, rows: &mut Vec<Row>) -> Vec<SearchReport> {
    let mut reports = Vec::new();
    for cache in [true, false] {
        let r = run_once(cache, params);
        rows.push(Row {
            label: format!("{tag} {}", if cache { "cache" } else { "no-cache" }),
            cells: vec![
                format!("{:.0}", r.throughput_qps),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p95_secs),
                fmt_secs(r.p99_secs),
                format!("{:.4}", r.recall_at_k),
                r.get_ops.to_string(),
                human_bytes(r.bytes_read),
                human_bytes(r.postings_bytes_fetched),
            ],
        });
        reports.push(r);
    }
    reports
}

fn main() {
    let mut params = match benchkit::scale() {
        Scale::Tiny => SearchParams::tiny(),
        Scale::Small => SearchParams::small(),
        Scale::Paper => SearchParams::paper(),
    };
    if let Ok(seed) = std::env::var("DT_SEED") {
        params.seed = seed.parse().expect("DT_SEED must be an integer");
    }
    let mut rows = Vec::new();
    let reports = bench_pair(&params, "flat", &mut rows);
    let pq_params = SearchParams { pq: true, ..params.clone() };
    let pq_reports = bench_pair(&pq_params, "pq", &mut rows);
    print_table(
        "search: closed-loop Zipfian top-k queries — Flat vs PQ postings, serving tier on vs off",
        &["mode", "q/s", "p50", "p95", "p99", "recall@k", "GETs", "bytes", "posting B"],
        &rows,
    );
    let speedup = reports[0].throughput_qps / reports[1].throughput_qps.max(1e-9);
    let pq_speedup = pq_reports[0].throughput_qps / pq_reports[1].throughput_qps.max(1e-9);
    let compression = reports[0].postings_bytes_fetched as f64
        / (pq_reports[0].postings_bytes_fetched as f64).max(1.0);
    println!("\nthroughput speedup with serving tier: flat {speedup:.2}x, pq {pq_speedup:.2}x");
    println!("posting bytes fetched, flat / pq: {compression:.1}x");

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".to_string());
    let json = format!(
        "{{\"bench\":\"search\",\"cache\":{},\"no_cache\":{},\"speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");

    let out_pq =
        std::env::var("DT_BENCH_OUT_PQ").unwrap_or_else(|_| "BENCH_search_pq.json".to_string());
    let json_pq = format!(
        "{{\"bench\":\"search_pq\",\"cache\":{},\"no_cache\":{},\"speedup\":{pq_speedup:.4},\
         \"posting_compression\":{compression:.4}}}",
        pq_reports[0].to_json(),
        pq_reports[1].to_json()
    );
    std::fs::write(&out_pq, json_pq).expect("write pq bench report");
    println!("wrote {out_pq}");
}
