//! E1 — reproduce **Figure 12**: dense-tensor performance on the FFHQ-like
//! workload, Binary baseline vs FTSF.
//!
//! Paper (5000×3×1024×1024 u8, S3 @1 Gbps):
//!
//! | method | storage | write | read tensor | read slice (100 imgs) |
//! |--------|---------|-------|-------------|-----------------------|
//! | Binary | 14.6 GB | 135.7s| 379.5s      | 494.3s                |
//! | FTSF   | 13.3 GB | 251.8s| 474.5s      | 49.2s                 |
//! | Δ      | −8.9 %  | +85.5%| +25.0%      | −90.0%                |
//!
//! We run a scaled tensor on the simulated link (`DT_SCALE` / `DT_NET`) and
//! report the same rows; the expected *shape* is: FTSF comparable-or-smaller
//! storage, slower writes/whole reads (more requests + commit protocol),
//! and an order-of-magnitude faster slice read.

use delta_tensor::benchkit::{self, fmt_pct, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::util::{human_bytes, RunStats, Stopwatch};
use delta_tensor::workload::{ffhq_like, FfhqParams};

fn fresh_table() -> DeltaTable {
    // These benches measure cold object-store reads (the paper's regime);
    // keep the serving tier's block cache out of the measurement.
    cold_table(DeltaTable::create(ObjectStoreHandle::sim_mem(benchkit::net()), "t").unwrap())
}

fn cold_table(table: DeltaTable) -> DeltaTable {
    delta_tensor::serving::set_cache_enabled(table.store().instance_id(), false);
    table
}

fn main() {
    let scale = benchkit::scale();
    let p = match scale {
        Scale::Tiny => FfhqParams { n: 32, channels: 3, height: 64, width: 64 },
        Scale::Small => FfhqParams { n: 128, channels: 3, height: 256, width: 256 },
        Scale::Paper => FfhqParams { n: 512, channels: 3, height: 512, width: 512 },
    };
    let reps = benchkit::reps(3);
    // Slice = "100 of 5000 images" scaled to 1/50 of the first dim, min 2.
    let slice_n = (p.n / 50).max(2);
    println!(
        "fig12: FFHQ-like {:?} = {} | net={:?} | reps={reps} | slice=first {slice_n} images",
        p.shape(),
        human_bytes(p.bytes() as u64),
        benchkit::net()
    );
    let data: TensorData = ffhq_like(42, p).into();

    let mut rows = Vec::new();
    let mut results: Vec<(f64, f64, f64, f64)> = Vec::new();
    for layout in ["Binary", "FTSF"] {
        let (size, write, read, slice) = run_one(layout, &data, slice_n, reps);
        results.push((size, write, read, slice));
        rows.push(Row {
            label: layout.into(),
            cells: vec![
                human_bytes(size as u64),
                fmt_secs(write),
                fmt_secs(read),
                fmt_secs(slice),
            ],
        });
    }
    let (bs, bw, br, bsl) = results[0];
    let (fs, fw, fr, fsl) = results[1];
    rows.push(Row {
        label: "Δ (FTSF vs Binary)".into(),
        cells: vec![
            fmt_pct(fs / bs - 1.0),
            fmt_pct(fw / bw - 1.0),
            fmt_pct(fr / br - 1.0),
            fmt_pct(fsl / bsl - 1.0),
        ],
    });
    print_table(
        "Figure 12 — dense tensor (Binary vs FTSF)",
        &["method", "storage", "write", "read tensor", "read slice"],
        &rows,
    );
    println!("\npaper Δ row: storage −8.90%  write +85.52%  read +25.02%  read-slice −90.04%");
}

fn run_one(layout: &str, data: &TensorData, slice_n: usize, reps: usize) -> (f64, f64, f64, f64) {
    let make_fmt = || -> Box<dyn TensorStore> {
        match layout {
            "Binary" => Box::new(BinaryFormat),
            _ => Box::new(FtsfFormat::new(3)), // chunk = one (C,H,W) image, Fig 2
        }
    };

    // Write timing on fresh tables each rep.
    let mut write = RunStats::new();
    for _ in 0..reps {
        let table = fresh_table();
        let fmt = make_fmt();
        let sw = Stopwatch::start();
        fmt.write(&table, "x", data).unwrap();
        write.push(sw.secs());
    }

    // One persistent table for reads + size.
    let table = fresh_table();
    let fmt = make_fmt();
    fmt.write(&table, "x", data).unwrap();
    let size = storage_bytes(&table, "x").unwrap() as f64;

    let mut read = RunStats::new();
    for _ in 0..reps {
        read.time(|| std::hint::black_box(fmt.read(&table, "x").unwrap()));
    }
    let slice = Slice::dim0(0, slice_n);
    let mut read_slice = RunStats::new();
    for _ in 0..reps {
        read_slice.time(|| std::hint::black_box(fmt.read_slice(&table, "x", &slice).unwrap()));
    }
    (size, write.mean(), read.mean(), read_slice.mean())
}
