//! Commit-pipeline contention bench: the closed-loop multi-writer workload
//! of `workload::contend`, run twice over fresh simulated cloud stores —
//! once with the full bursty fleet sharing tables (the contended regime the
//! arbitration layer exists for), once with one writer per table (the
//! uncontended control) — and compared on commit throughput, rebase rate
//! and commit-path latency. The contended run's `success_rate` is the
//! correctness bar: writers own disjoint tensors, so every race must be
//! absorbed by rebase, never surfaced to the client.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_BENCH_OUT` (JSON report path, default `BENCH_contend.json`). CI runs
//! the tiny scale, uploads the JSON, and gates on it via
//! `cargo run --bin benchgate` against `bench_baselines/contend.json`.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::workload::contend::{
    populate_contend, run_contend, ContendParams, ContendReport,
};

fn run_once(solo: bool, base: &ContendParams) -> ContendReport {
    let mut params = base.clone();
    if solo {
        // Same op count per writer, but every writer gets a private table
        // and the bursts are disabled: no shared log, no contention.
        params.tables = params.writers;
        params.burst_every = 0;
    }
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let tables = populate_contend(&store, &params).expect("populate contend tables");
    run_contend(&tables, &params).expect("contend run")
}

fn main() {
    let params = match benchkit::scale() {
        Scale::Tiny => ContendParams::tiny(),
        Scale::Small => ContendParams::small(),
        Scale::Paper => ContendParams::paper(),
    };
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for solo in [false, true] {
        let r = run_once(solo, &params);
        rows.push(Row {
            label: if solo { "solo" } else { "contended" }.to_string(),
            cells: vec![
                format!("{:.1}", r.ops_per_sec),
                format!("{:.4}", r.success_rate),
                r.rebases.to_string(),
                r.retries.to_string(),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p99_secs),
                r.log_commits.to_string(),
            ],
        });
        reports.push(r);
    }
    print_table(
        "contend: bursty multi-writer fleets on shared tables vs one table per writer",
        &["mode", "commits/s", "success", "rebases", "lost races", "p50", "p99", "commits"],
        &rows,
    );
    let slowdown = reports[1].ops_per_sec / reports[0].ops_per_sec.max(1e-9);
    println!("\ncontention cost: {slowdown:.2}x solo-vs-contended commit throughput");
    println!(
        "arbitration work: {} rebases, {} lost races, {} queue waits across {} commits",
        reports[0].rebases, reports[0].retries, reports[0].queue_waits, reports[0].commits
    );

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_contend.json".to_string());
    let json = format!(
        "{{\"bench\":\"contend\",\"contended\":{},\"solo\":{},\"slowdown\":{slowdown:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");
}
