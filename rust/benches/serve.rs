//! Serving-tier load bench: the closed-loop Zipfian hot-set workload of
//! `workload::serve`, run twice over a fresh simulated cloud store — once
//! through the block cache + single-flight serving tier, once straight to
//! the backend — and compared on throughput, latency quantiles, GETs and
//! bytes moved.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_BENCH_OUT` (JSON report path, default `BENCH_serve.json`),
//! `DT_HEALTH_OUT` (doctor report path, default `HEALTH_serve.json`). CI
//! runs the tiny scale and uploads the JSON so the perf trajectory
//! accumulates across commits.
//!
//! Every run probes the health gauges each iteration of client 0
//! (`probe_every = 1` — the trajectory rides the BENCH JSON, and the
//! telemetry-overhead runs below probe too, so the ≤5% ceiling CI gates
//! also bounds the probe's cost). After the measured runs the table doctor
//! audits the served table deep; `HEALTH_serve.json` feeds CI's
//! `tablecheck` bin, which fails on any corrupt finding.
//!
//! The bench also measures the telemetry tier's cost: the same warmed
//! cache-on workload with tracing off vs on (including the harness's
//! 1-in-`trace_every` forced-trace sampling), reported as `overhead_frac`
//! in `BENCH_telemetry.json` (`DT_BENCH_TELEMETRY_OUT`) and gated by CI at
//! an absolute 5% ceiling. The traces the telemetry-on runs sample are
//! exported as one Chrome trace_event document (`TRACE_serve.json`,
//! `DT_TRACE_OUT`) — load it in chrome://tracing or Perfetto.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::coordinator::Coordinator;
use delta_tensor::health::{doctor, DoctorOptions};
use delta_tensor::prelude::*;
use delta_tensor::telemetry;
use delta_tensor::util::human_bytes;
use delta_tensor::workload::serve::{populate_serve_table, run_serve, ServeParams, ServeReport};

fn run_once(cache: bool, params: &ServeParams) -> (ServeReport, Coordinator) {
    let mut params = params.clone();
    params.cache = cache;
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "serve").expect("fresh table");
    let c = Coordinator::new(table, 4, 32);
    let ids = populate_serve_table(&c, &params).expect("populate");
    let report = run_serve(&c, &ids, &params).expect("serve run");
    (report, c)
}

/// One warmed cache-on serving run with the runtime tracing flag forced to
/// `on`; returns the measured throughput. The flag also gates the
/// harness's forced-trace sampling, so the `off` control run is completely
/// trace-free — the delta between the two is exactly what tracing costs.
fn run_telemetry(on: bool, params: &ServeParams) -> f64 {
    let was = telemetry::enabled();
    telemetry::set_enabled(on);
    let (r, _) = run_once(true, params);
    telemetry::set_enabled(was);
    r.throughput_rps
}

fn main() {
    let mut params = match benchkit::scale() {
        Scale::Tiny => ServeParams::tiny(),
        Scale::Small => ServeParams::small(),
        Scale::Paper => ServeParams::paper(),
    };
    // Per-iteration health probing on client 0: the acceptance bar for the
    // probe's cost — the telemetry runs below inherit it, so the ≤5%
    // overhead ceiling CI gates covers probing too.
    params.probe_every = 1;
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    let mut coords = Vec::new();
    for cache in [true, false] {
        let (r, c) = run_once(cache, &params);
        coords.push(c);
        rows.push(Row {
            label: if cache { "cache" } else { "no-cache" }.to_string(),
            cells: vec![
                format!("{:.0}", r.throughput_rps),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p95_secs),
                fmt_secs(r.p99_secs),
                r.get_ops.to_string(),
                human_bytes(r.bytes_read),
            ],
        });
        reports.push(r);
    }
    print_table(
        "serve: closed-loop Zipfian reads, serving tier on vs off",
        &["mode", "req/s", "p50", "p95", "p99", "GETs", "bytes"],
        &rows,
    );
    let speedup = reports[0].throughput_rps / reports[1].throughput_rps.max(1e-9);
    println!("\nthroughput speedup with serving tier: {speedup:.2}x");

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = format!(
        "{{\"bench\":\"serve\",\"cache\":{},\"no_cache\":{},\"speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");

    // Deep doctor audit of the cache-on run's table: sizes, footers, chunk
    // crcs, grids and orphans all cross-checked against the log.
    let health = doctor(coords[0].table(), &DoctorOptions { deep: true }).expect("doctor run");
    assert_eq!(
        health.corrupts(),
        0,
        "served table must audit clean: {:?}",
        health.findings
    );
    let health_out =
        std::env::var("DT_HEALTH_OUT").unwrap_or_else(|_| "HEALTH_serve.json".to_string());
    std::fs::write(&health_out, health.to_json().dump()).expect("write health report");
    println!(
        "wrote {health_out} ({} objects, {} checks, {} warn / {} corrupt)",
        health.objects,
        health.checks,
        health.warns(),
        health.corrupts()
    );

    // Telemetry overhead: interleaved off/on repeats of the warmed
    // cache-on workload, best-of-3 per mode to damp scheduler noise.
    // `overhead_frac` is the QPS the tracing path costs; CI gates it at
    // the absolute 5% ceiling in bench_baselines/telemetry.json.
    telemetry::sink().clear();
    let mut off_rps = 0f64;
    let mut on_rps = 0f64;
    for _ in 0..3 {
        off_rps = off_rps.max(run_telemetry(false, &params));
        on_rps = on_rps.max(run_telemetry(true, &params));
    }
    let overhead_frac = (1.0 - on_rps / off_rps.max(1e-9)).max(0.0);
    println!(
        "\ntelemetry overhead: off {off_rps:.0} req/s vs on {on_rps:.0} req/s \
         ({:.2}% slower traced)",
        overhead_frac * 100.0
    );
    let tel_out = std::env::var("DT_BENCH_TELEMETRY_OUT")
        .unwrap_or_else(|_| "BENCH_telemetry.json".to_string());
    let tel_json = format!(
        "{{\"bench\":\"telemetry\",\"off_rps\":{off_rps:.4},\"on_rps\":{on_rps:.4},\
         \"overhead_frac\":{overhead_frac:.6}}}"
    );
    std::fs::write(&tel_out, tel_json).expect("write telemetry report");
    println!("wrote {tel_out}");

    // Export the traces the telemetry-on runs sampled as one Chrome
    // trace_event document — the CI artifact Perfetto loads directly,
    // structurally validated by the `tracecheck` bin.
    let traces = telemetry::sink().recent();
    let trace_out =
        std::env::var("DT_TRACE_OUT").unwrap_or_else(|_| "TRACE_serve.json".to_string());
    let doc = telemetry::export::chrome_trace_json(&traces);
    std::fs::write(&trace_out, doc.dump()).expect("write trace export");
    println!("wrote {trace_out} ({} sampled traces)", traces.len());
}
