//! Serving-tier load bench: the closed-loop Zipfian hot-set workload of
//! `workload::serve`, run twice over a fresh simulated cloud store — once
//! through the block cache + single-flight serving tier, once straight to
//! the backend — and compared on throughput, latency quantiles, GETs and
//! bytes moved.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_BENCH_OUT` (JSON report path, default `BENCH_serve.json`). CI runs
//! the tiny scale and uploads the JSON so the perf trajectory accumulates
//! across commits.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::coordinator::Coordinator;
use delta_tensor::prelude::*;
use delta_tensor::util::human_bytes;
use delta_tensor::workload::serve::{populate_serve_table, run_serve, ServeParams, ServeReport};

fn run_once(cache: bool, params: &ServeParams) -> ServeReport {
    let mut params = params.clone();
    params.cache = cache;
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "serve").expect("fresh table");
    let c = Coordinator::new(table, 4, 32);
    let ids = populate_serve_table(&c, &params).expect("populate");
    run_serve(&c, &ids, &params).expect("serve run")
}

fn main() {
    let params = match benchkit::scale() {
        Scale::Tiny => ServeParams::tiny(),
        Scale::Small => ServeParams::small(),
        Scale::Paper => ServeParams::paper(),
    };
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for cache in [true, false] {
        let r = run_once(cache, &params);
        rows.push(Row {
            label: if cache { "cache" } else { "no-cache" }.to_string(),
            cells: vec![
                format!("{:.0}", r.throughput_rps),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p95_secs),
                fmt_secs(r.p99_secs),
                r.get_ops.to_string(),
                human_bytes(r.bytes_read),
            ],
        });
        reports.push(r);
    }
    print_table(
        "serve: closed-loop Zipfian reads, serving tier on vs off",
        &["mode", "req/s", "p50", "p95", "p99", "GETs", "bytes"],
        &rows,
    );
    let speedup = reports[0].throughput_rps / reports[1].throughput_rps.max(1e-9);
    println!("\nthroughput speedup with serving tier: {speedup:.2}x");

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let json = format!(
        "{{\"bench\":\"serve\",\"cache\":{},\"no_cache\":{},\"speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");
}
