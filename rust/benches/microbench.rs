//! P1 + ablations — microbenchmarks behind the design choices listed in
//! DESIGN.md ("Key design choices"), plus the §Perf hot-path measurements:
//!
//! * `ftsf_dc`       — FTSF chunk rank Dc ∈ {2, 3} (paper Figs 2 vs 3)
//! * `bsgs_edge`     — BSGS block edge ∈ {4, 8, 16, 32} (paper §IV.F tradeoff)
//! * `rowgroup`      — COO rows-per-group sweep (pruning vs overhead)
//! * `codec`         — page codec none / zstd / deflate (size vs time)
//! * `coord_scaling` — coordinator worker count scaling
//! * `decode`        — sparse decode: CPU scatter vs XLA artifact vs memcpy
//!
//! Select one section with `--section NAME` (or env `DT_SECTION`); default
//! runs all. All sections run in-memory with no network simulation — these
//! measure compute, not the modeled link.

use delta_tensor::benchkit::{fmt_secs, print_table, Row};
use delta_tensor::coordinator::{Coordinator, IngestJob};
use delta_tensor::prelude::*;
use delta_tensor::util::{human_bytes, RunStats, Stopwatch};
use delta_tensor::workload;

fn fresh_table() -> DeltaTable {
    // These benches measure cold object-store reads (the paper's regime);
    // keep the serving tier's block cache out of the measurement.
    cold_table(DeltaTable::create(ObjectStoreHandle::mem(), "t").unwrap())
}

fn cold_table(table: DeltaTable) -> DeltaTable {
    delta_tensor::serving::set_cache_enabled(table.store().instance_id(), false);
    table
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let section = args
        .iter()
        .position(|a| a == "--section")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var("DT_SECTION").ok())
        .unwrap_or_else(|| "all".to_string());
    let run = |name: &str| section == "all" || section == name;

    if run("ftsf_dc") {
        ftsf_dc();
    }
    if run("bsgs_edge") {
        bsgs_edge();
    }
    if run("rowgroup") {
        rowgroup();
    }
    if run("codec") {
        codec();
    }
    if run("coord_scaling") {
        coord_scaling();
    }
    if run("decode") {
        decode();
    }
}

/// Ablation 1: FTSF chunk rank.
fn ftsf_dc() {
    let p = workload::FfhqParams { n: 64, channels: 3, height: 128, width: 128 };
    let data: TensorData = workload::ffhq_like(1, p).into();
    let mut rows = Vec::new();
    for dc in [2usize, 3] {
        let table = fresh_table();
        let fmt = FtsfFormat::new(dc);
        let sw = Stopwatch::start();
        fmt.write(&table, "x", &data).unwrap();
        let w = sw.secs();
        let size = storage_bytes(&table, "x").unwrap();
        let mut slice = RunStats::new();
        for i in 0..5 {
            let s = Slice::index(i * 12);
            slice.time(|| std::hint::black_box(fmt.read_slice(&table, "x", &s).unwrap()));
        }
        rows.push(Row {
            label: format!("Dc={dc}"),
            cells: vec![human_bytes(size), fmt_secs(w), fmt_secs(slice.mean())],
        });
    }
    print_table("ablation: FTSF chunk rank (Fig 2 vs Fig 3)", &["Dc", "size", "write", "slice"], &rows);
}

/// Ablation 2: BSGS block edge.
fn bsgs_edge() {
    let p = workload::UberParams { days: 48, hours: 24, grid_x: 128, grid_y: 196, events: 60_000, hotspots: 12 };
    let data: TensorData = workload::uber_like(2, p).into();
    let mut rows = Vec::new();
    for edge in [4usize, 8, 16, 32] {
        let table = fresh_table();
        let fmt = BsgsFormat::with_edge(edge);
        let sw = Stopwatch::start();
        fmt.write(&table, "u", &data).unwrap();
        let w = sw.secs();
        let size = storage_bytes(&table, "u").unwrap();
        let mut slice = RunStats::new();
        for i in 0..5 {
            let s = Slice::index(i * 9);
            slice.time(|| std::hint::black_box(fmt.read_slice(&table, "u", &s).unwrap()));
        }
        rows.push(Row {
            label: format!("edge={edge}"),
            cells: vec![human_bytes(size), fmt_secs(w), fmt_secs(slice.mean())],
        });
    }
    print_table(
        "ablation: BSGS block edge (too big wastes space, too small degenerates to COO)",
        &["block", "size", "write", "slice"],
        &rows,
    );
}

/// Ablation 3: COO row-group size.
fn rowgroup() {
    let p = workload::UberParams { days: 96, hours: 24, grid_x: 96, grid_y: 128, events: 120_000, hotspots: 12 };
    let data: TensorData = workload::uber_like(3, p).into();
    let mut rows = Vec::new();
    for rpg in [4 * 1024usize, 16 * 1024, 64 * 1024, 256 * 1024] {
        let table = fresh_table();
        let fmt = CooFormat { rows_per_group: rpg, ..Default::default() };
        fmt.write(&table, "u", &data).unwrap();
        let size = storage_bytes(&table, "u").unwrap();
        let mut slice = RunStats::new();
        for i in 0..5 {
            let s = Slice::index(i * 19);
            slice.time(|| std::hint::black_box(fmt.read_slice(&table, "u", &s).unwrap()));
        }
        let mut full = RunStats::new();
        full.time(|| std::hint::black_box(fmt.read(&table, "u").unwrap()));
        rows.push(Row {
            label: format!("{}k", rpg / 1024),
            cells: vec![human_bytes(size), fmt_secs(slice.mean()), fmt_secs(full.mean())],
        });
    }
    print_table(
        "ablation: COO rows per row group (pruning granularity vs per-group overhead)",
        &["rows/group", "size", "slice", "full read"],
        &rows,
    );
}

/// Ablation 4: page codec.
fn codec() {
    use delta_tensor::columnar::Codec;
    let p = workload::UberParams { days: 96, hours: 24, grid_x: 96, grid_y: 128, events: 120_000, hotspots: 12 };
    let data: TensorData = workload::uber_like(4, p).into();
    let mut rows = Vec::new();
    for (name, codec) in [
        ("none", Codec::None),
        ("zstd-1", Codec::Zstd(1)),
        ("zstd-3", Codec::Zstd(3)),
        ("zstd-9", Codec::Zstd(9)),
        ("deflate-6", Codec::Deflate(6)),
    ] {
        let table = fresh_table();
        let fmt = CooFormat { codec, ..Default::default() };
        let sw = Stopwatch::start();
        fmt.write(&table, "u", &data).unwrap();
        let w = sw.secs();
        let size = storage_bytes(&table, "u").unwrap();
        let mut read = RunStats::new();
        read.time(|| std::hint::black_box(fmt.read(&table, "u").unwrap()));
        rows.push(Row {
            label: name.into(),
            cells: vec![human_bytes(size), fmt_secs(w), fmt_secs(read.mean())],
        });
    }
    print_table("ablation: page compression codec (COO table)", &["codec", "size", "write", "read"], &rows);
}

/// §Perf L3: coordinator worker scaling.
fn coord_scaling() {
    let tensors: Vec<TensorData> = (0..16)
        .map(|i| {
            workload::ffhq_like(
                i,
                workload::FfhqParams { n: 16, channels: 3, height: 128, width: 128 },
            )
            .into()
        })
        .collect();
    let mut rows = Vec::new();
    let mut base = None;
    for workers in [1usize, 2, 4, 8] {
        let table = fresh_table();
        let c = Coordinator::new(table, workers, 32);
        let sw = Stopwatch::start();
        for (i, t) in tensors.iter().enumerate() {
            c.submit(IngestJob { id: format!("t{i}"), layout: "FTSF".into(), data: t.clone() });
        }
        let errs = c.drain();
        assert!(errs.is_empty(), "{errs:?}");
        let secs = sw.secs();
        let base_secs = *base.get_or_insert(secs);
        rows.push(Row {
            label: format!("{workers} workers"),
            cells: vec![
                fmt_secs(secs),
                format!("{:.2}x", base_secs / secs),
                format!("{:.0}%", base_secs / secs / workers as f64 * 100.0),
            ],
        });
    }
    print_table(
        "perf: coordinator ingest scaling (16 tensors, FTSF, mem store)",
        &["workers", "wall", "speedup", "efficiency"],
        &rows,
    );
}

/// §Perf L1/L2: sparse decode CPU vs XLA artifact vs memcpy roofline.
fn decode() {
    let slice = workload::generic_sparse(5, &[24, 64, 64], 0.02).unwrap();
    let dense_bytes = 24 * 64 * 64 * 4;
    let reps = 50;

    // memcpy roofline: copying the dense output once.
    let src = vec![0u8; dense_bytes];
    let mut memcpy = RunStats::new();
    for _ in 0..reps {
        memcpy.time(|| std::hint::black_box(src.clone()));
    }

    // CPU scatter decode.
    let mut cpu = RunStats::new();
    for _ in 0..reps {
        cpu.time(|| std::hint::black_box(slice.to_dense().unwrap()));
    }

    let mut rows = vec![
        Row {
            label: "memcpy roofline".into(),
            cells: vec![fmt_secs(memcpy.mean()), gbps(dense_bytes, memcpy.mean())],
        },
        Row {
            label: "CPU scatter".into(),
            cells: vec![fmt_secs(cpu.mean()), gbps(dense_bytes, cpu.mean())],
        },
    ];

    // XLA decode (only when artifacts exist).
    if let Ok(dir) = delta_tensor::runtime::default_artifact_dir() {
        if let Ok(rt) = delta_tensor::runtime::Runtime::open(dir) {
            // warm up compile
            let _ = delta_tensor::query::decode_slice_xla(&rt, &slice.clone().into()).unwrap();
            let mut xla = RunStats::new();
            for _ in 0..reps {
                xla.time(|| {
                    std::hint::black_box(
                        delta_tensor::query::decode_slice_xla(&rt, &slice.clone().into()).unwrap(),
                    )
                });
            }
            rows.push(Row {
                label: "XLA artifact".into(),
                cells: vec![fmt_secs(xla.mean()), gbps(dense_bytes, xla.mean())],
            });
        }
    }
    print_table(
        "perf: sparse slice decode (24,64,64), ~2% nnz",
        &["path", "time", "throughput"],
        &rows,
    );
}

fn gbps(bytes: usize, secs: f64) -> String {
    format!("{:.2} GB/s", bytes as f64 / secs / 1e9)
}
