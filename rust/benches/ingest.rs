//! Write-engine load bench: the closed-loop batched-ingest workload of
//! `workload::ingest`, run twice over a fresh simulated cloud store — once
//! committing multi-tensor batches through the write engine, once
//! committing one tensor per version (the seed's serial regime) — and
//! compared on throughput, per-commit latency, PUT batches and log growth.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_BENCH_OUT` (JSON report path, default `BENCH_ingest.json`). CI runs
//! the tiny scale, uploads the JSON, and gates on it via
//! `cargo run --bin benchgate` against `bench_baselines/ingest.json`.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::workload::ingest::{run_ingest, IngestParams, IngestReport};

fn run_once(serial: bool, base: &IngestParams) -> IngestReport {
    let mut params = base.clone();
    if serial {
        // Same total tensors, one per commit.
        params.batches_per_writer *= params.tensors_per_batch;
        params.tensors_per_batch = 1;
    }
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "ingest").expect("fresh table");
    run_ingest(&table, &params).expect("ingest run")
}

fn main() {
    let params = match benchkit::scale() {
        Scale::Tiny => IngestParams::tiny(),
        Scale::Small => IngestParams::small(),
        Scale::Paper => IngestParams::paper(),
    };
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for serial in [false, true] {
        let r = run_once(serial, &params);
        rows.push(Row {
            label: if serial { "serial" } else { "batched" }.to_string(),
            cells: vec![
                format!("{:.1}", r.throughput_tps),
                fmt_secs(r.p50_secs),
                fmt_secs(r.p95_secs),
                r.put_ops.to_string(),
                r.put_batches.to_string(),
                r.log_commits.to_string(),
            ],
        });
        reports.push(r);
    }
    print_table(
        "ingest: closed-loop batched writes, multi-tensor commits vs one-per-tensor",
        &["mode", "tensors/s", "p50", "p95", "PUTs", "PUT batches", "commits"],
        &rows,
    );
    let speedup = reports[0].throughput_tps / reports[1].throughput_tps.max(1e-9);
    println!("\nthroughput speedup from batched commits: {speedup:.2}x");
    println!(
        "log growth: {} versions batched vs {} serial",
        reports[0].log_commits, reports[1].log_commits
    );

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_ingest.json".to_string());
    let json = format!(
        "{{\"bench\":\"ingest\",\"batched\":{},\"serial\":{},\"speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");
}
