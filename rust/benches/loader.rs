//! Training-loader bench: the closed-loop shuffled-epoch workload of
//! `workload::loader`, streamed twice over a fresh simulated cloud store —
//! once through the planning + prefetching `DataLoader`, once through a
//! naive per-sample sequential reader visiting the same shuffled order —
//! and compared on samples/s, time-to-first-batch, stall fraction, and
//! cold/warm-epoch GET counts.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_BENCH_OUT` (JSON report path, default `BENCH_loader.json`). CI runs
//! the tiny scale and gates `loader.samples_per_sec` (relative floor),
//! `loader.time_to_first_batch_ms` (absolute ceiling) and `speedup`
//! (absolute floor) against `bench_baselines/loader.json`.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::coordinator::Coordinator;
use delta_tensor::prelude::*;
use delta_tensor::workload::loader::{run_loader_bench, LoaderParams, LoaderReport};

fn row(r: &LoaderReport) -> Row {
    Row {
        label: r.mode.clone(),
        cells: vec![
            format!("{:.0}", r.samples_per_sec),
            format!("{:.1}ms", r.time_to_first_batch_ms),
            fmt_secs(r.batch_mean_secs),
            fmt_secs(r.batch_p95_secs),
            format!("{:.0}%", r.stall_frac * 100.0),
            r.gets_cold.to_string(),
            r.gets_warm.to_string(),
        ],
    }
}

fn main() {
    let params = match benchkit::scale() {
        Scale::Tiny => LoaderParams::tiny(),
        Scale::Small => LoaderParams::small(),
        Scale::Paper => LoaderParams::paper(),
    };
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "loader").expect("fresh table");
    let c = Coordinator::new(table, 4, 32);
    let cmp = run_loader_bench(&c, &params).expect("loader bench");

    print_table(
        "loader: shuffled epoch streaming, DataLoader vs naive sequential reads",
        &["mode", "samples/s", "first batch", "mean", "p95", "stalls", "cold GETs", "warm GETs"],
        &[row(&cmp.loader), row(&cmp.naive)],
    );
    println!("\n{}", cmp.summary());

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_loader.json".to_string());
    std::fs::write(&out, cmp.to_json()).expect("write bench report");
    println!("wrote {out}");
}
