//! Maintenance-tier load bench: the closed-loop append/search/optimize
//! workload of `workload::maintain`, run twice over a fresh simulated
//! cloud store — once with incremental index upkeep (delta posting
//! segments in the append commit, folded on OPTIMIZE), once with the
//! rebuild-per-append control — and compared on append latency, search
//! QPS and recall.
//!
//! Knobs: `DT_SCALE` (tiny|small|paper), `DT_NET` (free|fast|paper|vpc),
//! `DT_SEED` (workload seed, default 7), `DT_BENCH_OUT` (JSON report path,
//! default `BENCH_maintain.json`), `DT_HEALTH_OUT` (doctor report path,
//! default `HEALTH_maintain.json`). CI runs the tiny scale and gates
//! `incremental.search_qps` against `bench_baselines/maintain.json`.
//!
//! Each run samples the health gauges once per round (the trajectory rides
//! the BENCH JSON), and after the incremental run the table doctor audits
//! the mutated table deep — the `HEALTH_maintain.json` artifact CI's
//! `tablecheck` bin fails on any corrupt finding.

use delta_tensor::benchkit::{self, fmt_secs, print_table, Row, Scale};
use delta_tensor::health::{doctor, DoctorOptions};
use delta_tensor::prelude::*;
use delta_tensor::workload::maintain::{
    populate_maintain_corpus, run_maintain, MaintainParams, MaintainReport,
};

fn run_once(incremental: bool, base: &MaintainParams) -> (MaintainReport, DeltaTable) {
    let mut params = base.clone();
    params.incremental = incremental;
    let store = ObjectStoreHandle::sim_mem(benchkit::net());
    let table = DeltaTable::create(store, "maintain").expect("fresh table");
    populate_maintain_corpus(&table, "vectors", &params).expect("populate");
    let report = run_maintain(&table, "vectors", &params).expect("maintain run");
    (report, table)
}

fn main() {
    let mut params = match benchkit::scale() {
        Scale::Tiny => MaintainParams::tiny(),
        Scale::Small => MaintainParams::small(),
        Scale::Paper => MaintainParams::paper(),
    };
    if let Ok(seed) = std::env::var("DT_SEED") {
        params.seed = seed.parse().expect("DT_SEED must be an integer");
    }
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    let mut tables = Vec::new();
    for incremental in [true, false] {
        let (r, table) = run_once(incremental, &params);
        tables.push(table);
        assert!(r.exact_full_nprobe, "full-nprobe search must equal brute force");
        rows.push(Row {
            label: if incremental { "incremental" } else { "rebuild" }.to_string(),
            cells: vec![
                fmt_secs(r.append_p50_secs),
                fmt_secs(r.append_p99_secs),
                format!("{:.0}", r.search_qps),
                fmt_secs(r.search_p99_secs),
                format!("{:.4}", r.recall_after_maintenance),
                r.full_rebuilds.to_string(),
                fmt_secs(r.optimize_secs),
            ],
        });
        reports.push(r);
    }
    let headers = [
        "mode", "append p50", "append p99", "q/s", "search p99", "recall@k", "rebuilds",
        "optimize",
    ];
    print_table(
        "maintain: append/search/optimize loop, incremental upkeep vs rebuild-per-append",
        &headers,
        &rows,
    );
    let speedup =
        reports[1].append_mean_secs.max(1e-9) / reports[0].append_mean_secs.max(1e-9);
    println!("\nappend-path speedup from incremental upkeep: {speedup:.2}x");
    println!(
        "recall: {:.4} maintained vs {:.4} control (full rebuild)",
        reports[0].recall_after_maintenance, reports[0].recall_full_rebuild
    );

    let out = std::env::var("DT_BENCH_OUT").unwrap_or_else(|_| "BENCH_maintain.json".to_string());
    let json = format!(
        "{{\"bench\":\"maintain\",\"incremental\":{},\"rebuild\":{},\
         \"append_speedup\":{speedup:.4}}}",
        reports[0].to_json(),
        reports[1].to_json()
    );
    std::fs::write(&out, json).expect("write bench report");
    println!("wrote {out}");

    // Deep doctor audit of the incrementally-maintained table: every chunk
    // crc-verified, every index artifact decoded. Any corrupt finding here
    // means the maintenance tier wrote state the log can't vouch for.
    let health = doctor(&tables[0], &DoctorOptions { deep: true }).expect("doctor run");
    assert_eq!(
        health.corrupts(),
        0,
        "maintained table must audit clean: {:?}",
        health.findings
    );
    let health_out =
        std::env::var("DT_HEALTH_OUT").unwrap_or_else(|_| "HEALTH_maintain.json".to_string());
    std::fs::write(&health_out, health.to_json().dump()).expect("write health report");
    println!(
        "wrote {health_out} ({} objects, {} checks, {} warn / {} corrupt)",
        health.objects,
        health.checks,
        health.warns(),
        health.corrupts()
    );
}
