//! E2-E5 — reproduce **Figures 13-16**: sparse-tensor storage size, write
//! time, whole-read time and slice-read time on the Uber-pickups-like
//! tensor, PT baseline vs COO / CSR / CSF / BSGS.
//!
//! Paper headline shapes (Uber tensor (183,24,1140,1717), 3.3 M nnz):
//!   Fig 13: every format ≤ 13.23 % of PT size; BSGS best at 4.83 %.
//!   Fig 14: CSF fastest write (−26.68 % vs PT).
//!   Fig 15: BSGS fastest whole read (−29.59 % vs PT).
//!   Fig 16: COO/CSF/BSGS beat PT on X[i] slices; BSGS best (−55.34 %).
//!
//! CSC is skipped as in the paper ("interchangeable nature of CSR and CSC").

use delta_tensor::benchkit::{self, fmt_pct, fmt_secs, print_table, Row, Scale};
use delta_tensor::prelude::*;
use delta_tensor::util::{human_bytes, Pcg64, RunStats, Stopwatch};
use delta_tensor::workload::{uber_like, UberParams};

type MakeFmt = Box<dyn Fn() -> Box<dyn TensorStore>>;

fn formats() -> Vec<(&'static str, MakeFmt)> {
    vec![
        ("PT", Box::new(|| Box::new(BinaryFormat) as Box<dyn TensorStore>)),
        ("COO", Box::new(|| Box::new(CooFormat::default()) as Box<dyn TensorStore>)),
        ("CSR", Box::new(|| Box::new(CsrFormat::default()) as Box<dyn TensorStore>)),
        ("CSF", Box::new(|| Box::new(CsfFormat::default()) as Box<dyn TensorStore>)),
        // Block shape tuned for the spatio-temporal workload (paper §IV.F:
        // block size is a workload input): full hour extent, 4x4 spatial.
        ("BSGS", Box::new(|| {
            Box::new(BsgsFormat::with_block_shape(&[1, 24, 4, 4])) as Box<dyn TensorStore>
        })),
    ]
}

fn fresh_table() -> DeltaTable {
    // These benches measure cold object-store reads (the paper's regime);
    // keep the serving tier's block cache out of the measurement.
    cold_table(DeltaTable::create(ObjectStoreHandle::sim_mem(benchkit::net()), "t").unwrap())
}

fn cold_table(table: DeltaTable) -> DeltaTable {
    delta_tensor::serving::set_cache_enabled(table.store().instance_id(), false);
    table
}

fn main() {
    let p = match benchkit::scale() {
        Scale::Tiny => UberParams::tiny(),
        Scale::Small => UberParams::default_scale(),
        Scale::Paper => UberParams::paper_scale(),
    };
    // The paper averages 100 repetitions; network-bound budget we scale
    // down (override with DT_REPS).
    let reps = benchkit::reps(5);
    let tensor = uber_like(42, p);
    println!(
        "fig13-16: Uber-like {:?}, nnz={} (density {:.4}%) | net={:?} | reps={reps}",
        p.shape(),
        tensor.nnz(),
        tensor.density() * 100.0,
        benchkit::net()
    );
    let data: TensorData = tensor.clone().into();
    let mut rng = Pcg64::new(7);

    let mut size_rows = Vec::new();
    let mut write_rows = Vec::new();
    let mut read_rows = Vec::new();
    let mut slice_rows = Vec::new();
    let mut pt_base: Option<(f64, f64, f64, f64)> = None;

    for (name, make) in formats() {
        let mut write = RunStats::new();
        for _ in 0..reps {
            let table = fresh_table();
            let fmt = make();
            let sw = Stopwatch::start();
            fmt.write(&table, "u", &data).unwrap();
            write.push(sw.secs());
        }
        let table = fresh_table();
        let fmt = make();
        fmt.write(&table, "u", &data).unwrap();
        let size = storage_bytes(&table, "u").unwrap() as f64;
        let mut read = RunStats::new();
        for _ in 0..reps {
            read.time(|| std::hint::black_box(fmt.read(&table, "u").unwrap()));
        }
        let mut rslice = RunStats::new();
        for _ in 0..reps {
            let day = rng.below(p.days);
            let slice = Slice::index(day);
            rslice.time(|| std::hint::black_box(fmt.read_slice(&table, "u", &slice).unwrap()));
        }

        let (w, r, s) = (write.mean(), read.mean(), rslice.mean());
        if name == "PT" {
            pt_base = Some((size, w, r, s));
        }
        let (bs, bw, br, bsl) = pt_base.unwrap();
        let rel = |x: f64, b: f64| {
            if name == "PT" {
                "—".to_string()
            } else {
                fmt_pct(x / b - 1.0)
            }
        };
        size_rows.push(Row {
            label: name.into(),
            cells: vec![human_bytes(size as u64), format!("{:.2}%", size / bs * 100.0)],
        });
        write_rows.push(Row { label: name.into(), cells: vec![fmt_secs(w), rel(w, bw)] });
        read_rows.push(Row { label: name.into(), cells: vec![fmt_secs(r), rel(r, br)] });
        slice_rows.push(Row { label: name.into(), cells: vec![fmt_secs(s), rel(s, bsl)] });
    }

    print_table(
        "Figure 13 — storage size (Cr = size/PT; paper: all ≤13.23%, BSGS 4.83%)",
        &["method", "size", "Cr"],
        &size_rows,
    );
    print_table(
        "Figure 14 — write time (paper: CSF best, −26.68% vs PT)",
        &["method", "t_write", "vs PT"],
        &write_rows,
    );
    print_table(
        "Figure 15 — read entire tensor (paper: BSGS best, −29.59% vs PT)",
        &["method", "t_read", "vs PT"],
        &read_rows,
    );
    print_table(
        "Figure 16 — read slice X[i,:,:,:] (paper: BSGS best, −55.34% vs PT)",
        &["method", "t_slice", "vs PT"],
        &slice_rows,
    );
}
