//! End-to-end training-loop walkthrough for the loader tier: write a
//! corpus, stream shuffled epochs through a [`DataLoader`], checkpoint
//! mid-epoch, resume from the checkpoint, and print the achieved
//! samples/s. Referenced from `ARCHITECTURE.md` ("life of a batch").
//!
//! Run with:
//!
//! ```text
//! cargo run --release --example train_loop
//! ```

use delta_tensor::coordinator::Coordinator;
use delta_tensor::loader::{Checkpoint, DataLoader, LoaderOptions};
use delta_tensor::prelude::*;
use delta_tensor::workload;

fn main() -> delta_tensor::Result<()> {
    // 1. Store a [512, 64] f32 corpus as FTSF with chunk rank 1, so the
    //    leading dimension — the sample axis — is the slicing axis.
    let table = DeltaTable::create(ObjectStoreHandle::sim_mem(CostModel::fast_sim()), "train")?;
    let c = Coordinator::new(table, 4, 32);
    let corpus: TensorData = workload::embedding_like(42, 512, 64, 8, 0.05).into();
    let fmt = FtsfFormat { rows_per_group: 16, rows_per_file: 128, ..FtsfFormat::new(1) };
    fmt.write(c.table(), "corpus", &corpus)?;
    println!("stored corpus: shape {:?}", corpus.shape());

    // 2. Open a loader: seeded shuffle, double-buffered prefetch. The
    //    decoded prefetch buffer is bounded by DT_PREFETCH_MB (default 64).
    let opts = LoaderOptions { batch_size: 32, seed: 7, ..Default::default() };
    let loader = DataLoader::open(&c, "corpus", opts)?;
    println!(
        "loader: {} samples x {:?}, {} batches/epoch, prefetch budget {} bytes",
        loader.n_samples(),
        loader.sample_shape(),
        loader.batches_per_epoch(),
        loader.prefetch_budget()
    );

    // 3. Epoch 0: train until a simulated preemption after 5 batches,
    //    persist the checkpoint (two integers — trivially serializable).
    let sw = std::time::Instant::now();
    let mut samples = 0u64;
    let mut it = loader.epoch(0)?;
    for _ in 0..5 {
        let batch = it.next_batch()?.expect("epoch 0 has 16 batches");
        samples += batch.rows.len() as u64;
        train_step(&batch);
    }
    let ckpt: Checkpoint = it.checkpoint();
    drop(it);
    println!("preempted at epoch {} cursor {}", ckpt.epoch, ckpt.cursor);

    // 4. Resume: the loader regenerates epoch 0's permutation from the
    //    seed and continues with exactly the batches not yet consumed.
    let mut it = loader.resume(ckpt)?;
    while let Some(batch) = it.next_batch()? {
        samples += batch.rows.len() as u64;
        train_step(&batch);
    }

    // 5. Epoch 1 runs warm: every fetch rides the serving tier's block
    //    cache, so it issues far fewer GETs than the cold epoch 0.
    for batch in loader.epoch(1)? {
        let batch = batch?;
        samples += batch.rows.len() as u64;
        train_step(&batch);
    }

    let secs = sw.elapsed().as_secs_f64();
    println!(
        "streamed {samples} samples in {secs:.3}s -> {:.0} samples/s \
         (peak prefetch buffer {} bytes)",
        samples as f64 / secs.max(1e-9),
        loader.max_buffered_bytes()
    );
    println!("{}", c.report());
    Ok(())
}

/// Stand-in for the gradient step: checksum the batch so the fetch is not
/// optimized away.
fn train_step(batch: &delta_tensor::loader::Batch) {
    std::hint::black_box(batch.data.bytes().iter().map(|&b| b as u64).sum::<u64>());
}
