"""AOT export sanity: every entry point lowers to parseable HLO text whose
parameter shapes match the manifest, and the lowered modules are pure data
(no python callbacks / custom-calls the CPU PJRT client cannot run)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    return out


def test_all_entry_points_exported(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    assert set(manifest) == set(aot.exports())
    for name, meta in manifest.items():
        assert (exported / meta["file"]).exists(), name


def test_hlo_text_is_wellformed(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    for name, meta in manifest.items():
        text = (exported / meta["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # interpret=True must have erased all Mosaic/pallas custom calls.
        assert "mosaic" not in text.lower(), name


def test_manifest_shapes_match_export_table(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    table = aot.exports()
    for name, meta in manifest.items():
        _, args = table[name]
        assert len(meta["inputs"]) == len(args)
        for arg_meta, arg in zip(meta["inputs"], args):
            assert tuple(arg_meta["shape"]) == tuple(arg.shape)


def test_exports_execute_under_jit():
    # The lowered functions must also run (interpret path) with real inputs.
    import numpy as np
    import jax.numpy as jnp

    for name, (fn, args) in aot.exports().items():
        concrete = [
            jnp.asarray(np.zeros(a.shape, dtype=a.dtype)) for a in args
        ]
        out = fn(*concrete)
        assert isinstance(out, tuple) and len(out) >= 1, name
