"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes, nnz counts, block geometries and value ranges;
every Pallas kernel must match its pure-jnp reference bit-for-bit (they
run the same f32 ops in the same order through interpret mode, so exact
equality is the right bar; allclose is used where reduction order differs).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import block_gather, coo_scatter, normalize
from compile.kernels.ref import (
    block_gather_ref,
    coo_scatter_ref,
    decode_pipeline_ref,
    normalize_ref,
)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


# ---------------------------------------------------------------- helpers


def padded_coo(rng, shape, nnz, cap):
    """Random distinct coordinates + values, padded to `cap` rows."""
    total = int(np.prod(shape))
    nnz = min(nnz, total)
    flat = rng.choice(total, size=nnz, replace=False)
    idx = np.zeros((cap, len(shape)), dtype=np.int32)
    vals = np.zeros((cap,), dtype=np.float32)
    rem = flat
    for d in range(len(shape) - 1, -1, -1):
        idx[:nnz, d] = rem % shape[d]
        rem = rem // shape[d]
    vals[:nnz] = rng.integers(1, 100, size=nnz).astype(np.float32)
    return idx, vals


shapes_2d = st.tuples(st.integers(2, 24), st.integers(2, 24))
shapes_3d = st.tuples(st.integers(2, 10), st.integers(2, 12), st.integers(2, 12))


# ---------------------------------------------------------------- coo_scatter


@given(shape=shapes_2d, nnz=st.integers(0, 64), seed=st.integers(0, 2**32 - 1))
def test_coo_scatter_2d_matches_ref(shape, nnz, seed):
    rng = np.random.default_rng(seed)
    idx, vals = padded_coo(rng, shape, nnz, cap=64)
    got = coo_scatter(jnp.asarray(idx), jnp.asarray(vals), shape=shape)
    want = coo_scatter_ref(jnp.asarray(idx), jnp.asarray(vals), shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(shape=shapes_3d, nnz=st.integers(1, 128), seed=st.integers(0, 2**32 - 1))
def test_coo_scatter_3d_matches_ref(shape, nnz, seed):
    rng = np.random.default_rng(seed)
    idx, vals = padded_coo(rng, shape, nnz, cap=128)
    got = coo_scatter(jnp.asarray(idx), jnp.asarray(vals), shape=shape)
    want = coo_scatter_ref(jnp.asarray(idx), jnp.asarray(vals), shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_coo_scatter_duplicates_accumulate():
    idx = jnp.asarray([[1, 1], [1, 1], [0, 0]], dtype=jnp.int32)
    vals = jnp.asarray([2.0, 3.0, 7.0], dtype=jnp.float32)
    got = np.asarray(coo_scatter(idx, vals, shape=(2, 2)))
    assert got[1, 1] == 5.0 and got[0, 0] == 7.0


def test_coo_scatter_all_padding_is_zero():
    idx = jnp.zeros((16, 2), dtype=jnp.int32)
    vals = jnp.zeros((16,), dtype=jnp.float32)
    got = np.asarray(coo_scatter(idx, vals, shape=(4, 4)))
    assert not got.any()


# ---------------------------------------------------------------- block_gather


@given(
    grid=st.tuples(st.integers(1, 6), st.integers(1, 6)),
    block=st.tuples(st.integers(1, 8), st.integers(1, 8)),
    nblocks=st.integers(0, 20),
    seed=st.integers(0, 2**32 - 1),
)
def test_block_gather_matches_ref(grid, block, nblocks, seed):
    rng = np.random.default_rng(seed)
    gr, gc = grid
    bh, bw = block
    cap = 24
    nblocks = min(nblocks, gr * gc)
    slots = rng.choice(gr * gc, size=nblocks, replace=False)
    idx = np.zeros((cap, 2), dtype=np.int32)
    vals = np.zeros((cap, bh, bw), dtype=np.float32)
    idx[:nblocks, 0] = slots // gc
    idx[:nblocks, 1] = slots % gc
    vals[:nblocks] = rng.integers(0, 50, size=(nblocks, bh, bw)).astype(np.float32)
    got = block_gather(jnp.asarray(idx), jnp.asarray(vals), grid=grid)
    want = block_gather_ref(jnp.asarray(idx), jnp.asarray(vals), grid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_gather_exact_paper_figure7():
    # BCSR example from the paper's Figure 7: 4x6 tensor, 2x3 blocks.
    idx = jnp.asarray([[0, 0], [1, 0], [1, 1]], dtype=jnp.int32)
    vals = jnp.asarray(
        [
            [[1, 0, 2], [0, 3, 0]],
            [[4, 0, 0], [0, 5, 0]],
            [[0, 6, 0], [7, 0, 8]],
        ],
        dtype=jnp.float32,
    )
    got = np.asarray(block_gather(idx, vals, grid=(2, 2)))
    assert got.shape == (4, 6)
    assert got[0, 0] == 1 and got[1, 1] == 3 and got[2, 0] == 4 and got[3, 3] == 7


# ---------------------------------------------------------------- normalize


@given(
    b=st.integers(1, 4),
    c=st.integers(1, 3),
    h=st.sampled_from([4, 8, 16]),
    w=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**32 - 1),
)
def test_normalize_matches_ref(b, c, h, w, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=(b, c, h, w), dtype=np.uint8)
    got = normalize(jnp.asarray(x))
    want = normalize_ref(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_normalize_range():
    x = np.zeros((1, 1, 4, 4), dtype=np.uint8)
    lo = np.asarray(normalize(jnp.asarray(x)))
    x[:] = 255
    hi = np.asarray(normalize(jnp.asarray(x)))
    assert np.allclose(lo, -2.0) and np.allclose(hi, 2.0)


# ---------------------------------------------------------------- L2 pipeline


def test_decode_pipeline_fuses_scatter_and_normalize():
    from compile.model import decode_coo

    rng = np.random.default_rng(0)
    shape = (4, 8, 8)
    idx, vals = padded_coo(rng, shape, nnz=40, cap=64)
    (got,) = decode_coo(jnp.asarray(idx), jnp.asarray(vals), shape=shape)
    want = (coo_scatter_ref(jnp.asarray(idx), jnp.asarray(vals), shape) / 255.0 - 0.5) * 4.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.uint8, np.int32])
def test_normalize_rejects_only_u8_like_semantics(dtype):
    # normalize() is defined on u8 batches; other int dtypes still work
    # numerically through astype, documenting the contract.
    x = np.zeros((1, 1, 4, 4), dtype=dtype)
    out = np.asarray(normalize(jnp.asarray(x).astype(jnp.uint8)))
    assert out.dtype == np.float32
