"""Layer-2 JAX compute graphs — the pipelines AOT-exported for the rust
read path.

Each entry point composes the L1 Pallas kernels into the computation a
training job runs on data read back from the Delta Tensor store. The rust
runtime (rust/src/runtime) loads the lowered HLO and executes it via PJRT;
python never runs at serving time.

Entry points (shapes fixed per export, see EXPORTS in aot.py):

* ``preprocess_chunks`` — FTSF read path: u8 chunk batch -> normalized f32.
* ``decode_coo``        — COO/CSR/CSF read path: padded nnz -> dense slice,
                          fused with normalization.
* ``decode_blocks``     — BSGS read path: dense blocks -> plane.
"""

from __future__ import annotations

from .kernels import block_gather, coo_scatter, normalize


def preprocess_chunks(chunks_u8):
    """u8[B, C, H, W] FTSF chunks -> normalized f32 batch."""
    return (normalize(chunks_u8),)


def decode_coo(indices, values, *, shape):
    """Padded COO (i32[N, nd], f32[N]) -> dense f32[shape], normalized.

    The fusion target: materialization and normalization lower into one XLA
    module so the intermediate dense tensor never round-trips to HBM twice.
    """
    dense = coo_scatter(indices, values, shape=shape)
    return ((dense * (1.0 / 255.0) - 0.5) * 4.0,)


def decode_coo_raw(indices, values, *, shape):
    """Padded COO -> dense f32[shape] (no normalization)."""
    return (coo_scatter(indices, values, shape=shape),)


def decode_coo_fast(indices, values, *, shape):
    """Padded COO -> dense via XLA's native scatter-add (no Pallas).

    The Pallas kernel (`decode_coo_raw`) is the TPU-shaped artifact; under
    interpret=True its fori_loop scatter lowers to a sequential HLO while
    loop, which the CPU backend executes orders of magnitude slower than
    its native scatter op. The rust runtime prefers this entry point when
    serving on CPU and keeps the Pallas artifact for TPU targets.
    """
    from .kernels.ref import coo_scatter_ref

    return (coo_scatter_ref(indices, values, shape),)


def decode_blocks(block_idx, block_vals, *, grid):
    """BSGS blocks (i32[NB, 2], f32[NB, BH, BW]) -> dense plane."""
    return (block_gather(block_idx, block_vals, grid=grid),)
