"""Layer-1 Pallas kernels (build-time only; never on the request path)."""

from .coo_scatter import coo_scatter
from .block_gather import block_gather
from .normalize import normalize

__all__ = ["coo_scatter", "block_gather", "normalize"]
