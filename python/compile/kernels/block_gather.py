"""Pallas kernel: BSGS dense-block gather -> dense plane.

Each stored block is a dense (BH, BW) payload with a block-grid coordinate;
the kernel accumulates every block into its slot of the output plane. On a
real TPU the output plane tiles across VMEM in (8·k, 128·m) lanes and blocks
stream from HBM; interpret=True executes the same schedule on CPU.

Padding convention: surplus block slots carry coordinate (0, 0) and all-zero
values, so accumulation is a no-op for them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(idx_ref, vals_ref, o_ref, *, bh, bw):
    o_ref[...] = jnp.zeros_like(o_ref)
    nb = vals_ref.shape[0]

    def body(b, _):
        r = idx_ref[b, 0] * bh
        c = idx_ref[b, 1] * bw
        cur = pl.load(o_ref, (pl.dslice(r, bh), pl.dslice(c, bw)))
        pl.store(o_ref, (pl.dslice(r, bh), pl.dslice(c, bw)), cur + vals_ref[b])
        return 0

    jax.lax.fori_loop(0, nb, body, 0)


@functools.partial(jax.jit, static_argnames=("grid",))
def block_gather(block_idx, block_vals, *, grid):
    """Assemble dense blocks into a (GR*BH, GC*BW) plane.

    Args:
      block_idx: i32[NB, 2] block-grid coordinates ((0,0) for padding).
      block_vals: f32[NB, BH, BW] block payloads (zeros for padding).
      grid: static (GR, GC) block-grid shape.

    Returns:
      f32[GR*BH, GC*BW].
    """
    nb, bh, bw = block_vals.shape
    gr, gc = grid
    return pl.pallas_call(
        functools.partial(_gather_kernel, bh=bh, bw=bw),
        out_shape=jax.ShapeDtypeStruct((gr * bh, gc * bw), block_vals.dtype),
        interpret=True,
    )(block_idx.astype(jnp.int32), block_vals)
