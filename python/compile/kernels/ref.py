"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis in python/tests). They define the exact semantics of the decode
hot path the rust read pipeline offloads to XLA:

* ``coo_scatter_ref``   — padded-COO -> dense materialization.
* ``block_gather_ref``  — BSGS dense-block collection -> dense plane.
* ``normalize_ref``     — u8 image chunk -> normalized f32 training batch.
"""

from __future__ import annotations

import jax.numpy as jnp


def coo_scatter_ref(indices, values, shape):
    """Scatter padded COO entries into a dense tensor.

    Args:
      indices: i32[N, ndim] coordinates; padded rows must point at a valid
        cell (conventionally all-zero) and carry value 0.
      values: f32[N] values, 0 for padding.
      shape: static output shape.

    Returns:
      f32[shape] with duplicate coordinates accumulated (padding adds 0).
    """
    out = jnp.zeros(shape, dtype=values.dtype)
    return out.at[tuple(indices[:, d] for d in range(len(shape)))].add(values)


def block_gather_ref(block_idx, block_vals, grid):
    """Assemble dense blocks into a dense plane.

    Args:
      block_idx: i32[NB, 2] block-grid (row, col) coordinates; padding blocks
        must target block (0, 0) and carry all-zero values.
      block_vals: f32[NB, BH, BW] dense block payloads.
      grid: static (GR, GC) block-grid shape; output is (GR*BH, GC*BW).

    Returns:
      f32[GR*BH, GC*BW] with blocks accumulated at their grid slots.
    """
    nb, bh, bw = block_vals.shape
    gr, gc = grid
    out = jnp.zeros((gr, gc, bh, bw), dtype=block_vals.dtype)
    out = out.at[block_idx[:, 0], block_idx[:, 1]].add(block_vals)
    return out.transpose(0, 2, 1, 3).reshape(gr * bh, gc * bw)


def normalize_ref(x, mean=0.5, std=0.25):
    """u8 image chunk -> f32 normalized to (x/255 - mean) / std."""
    return (x.astype(jnp.float32) / 255.0 - mean) / std


def decode_pipeline_ref(indices, values, shape, mean=0.5, std=0.25):
    """The fused L2 pipeline: sparse decode -> scale -> normalize.

    Models "read sparse tensor from the lakehouse, materialize, and prep a
    training batch" as one XLA computation.
    """
    dense = coo_scatter_ref(indices, values, shape)
    return (dense / 255.0 - mean) / std
