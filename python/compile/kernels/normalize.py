"""Pallas kernel: u8 image chunk -> normalized f32 training batch.

The classic data-pipeline preprocessing step applied to FTSF chunks as they
come off the object store. Tiled elementwise: the grid walks the batch
dimension so each step normalizes one (C, H, W) chunk — a BlockSpec schedule
that keeps each VMEM tile at C·H·W·4 bytes (≈3 MiB at 3×512×512 it would
split further; the exported shapes keep tiles ≤2 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _normalize_kernel(x_ref, o_ref, *, mean, std):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x * (1.0 / 255.0) - mean) * (1.0 / std)


@functools.partial(jax.jit, static_argnames=("mean", "std"))
def normalize(x, *, mean=0.5, std=0.25):
    """Normalize a u8 batch [B, C, H, W] to f32 (x/255 - mean)/std."""
    b, c, h, w = x.shape
    return pl.pallas_call(
        functools.partial(_normalize_kernel, mean=mean, std=std),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, h, w), jnp.float32),
        interpret=True,
    )(x)
