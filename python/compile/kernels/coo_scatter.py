"""Pallas kernel: padded-COO -> dense scatter (the sparse decode hot-spot).

TPU-shaped even though we run interpret=True on CPU (see DESIGN.md
§Hardware-Adaptation): the output tile lives in VMEM; the nnz stream is
consumed in fixed-size index blocks from HBM. A CUDA implementation would
assign nnz ranges to threadblocks and atomically add into global memory —
on TPU we instead keep the output tile resident and serialize the scatter
through a fori_loop, which the (single-core) interpret path executes
identically.

The kernel flattens coordinates with precomputed row-major strides and
scatter-adds values, so padded rows (index 0, value 0) are harmless no-ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(idx_ref, val_ref, o_ref, *, strides, out_numel):
    """Scatter one nnz block into the flat output tile (VMEM-resident)."""
    o_ref[...] = jnp.zeros_like(o_ref)
    n = val_ref.shape[0]
    flat = jnp.zeros((n,), dtype=jnp.int32)
    for d, s in enumerate(strides):
        flat = flat + idx_ref[:, d] * s
    flat = jnp.clip(flat, 0, out_numel - 1)

    def body(i, _):
        f = flat[i]
        pl.store(o_ref, (f,), pl.load(o_ref, (f,)) + val_ref[i])
        return 0

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit, static_argnames=("shape",))
def coo_scatter(indices, values, *, shape):
    """Materialize padded COO entries as a dense f32 tensor of `shape`.

    Args:
      indices: i32[N, ndim]; padding rows point at cell 0 with value 0.
      values: f32[N].
      shape: static output shape.

    Returns:
      f32[shape]; duplicates accumulate.
    """
    ndim = len(shape)
    assert indices.ndim == 2 and indices.shape[1] == ndim
    out_numel = 1
    for d in shape:
        out_numel *= d
    strides = [1] * ndim
    for d in range(ndim - 2, -1, -1):
        strides[d] = strides[d + 1] * shape[d + 1]

    flat = pl.pallas_call(
        functools.partial(_scatter_kernel, strides=tuple(strides), out_numel=out_numel),
        out_shape=jax.ShapeDtypeStruct((out_numel,), values.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(indices.astype(jnp.int32), values)
    return flat.reshape(shape)
