"""AOT export: lower the L2 entry points to HLO text + a manifest.

HLO *text* is the interchange format (NOT ``lowered.compiler_ir("hlo")`` or
serialized protos): jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids that the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes ``<name>.hlo.txt`` per entry point plus ``manifest.json`` describing
the argument shapes/dtypes, which the rust runtime loads to validate inputs.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Export table: name -> (fn, example args). Shapes here must match the rust
# side's runtime::ArtifactSpec defaults (see rust/src/runtime/mod.rs).
# ---------------------------------------------------------------------------

# FTSF preprocess: a batch of 8 RGB 64x64 chunks (one VMEM tile each: 48 KiB).
PREPROCESS_SHAPE = (8, 3, 64, 64)
# COO decode: an Uber-like first-dim slice (24, 64, 64) with nnz capacity 8192.
DECODE_SHAPE = (24, 64, 64)
DECODE_NNZ = 8192
# BSGS decode: a 16x16 grid of 16x16 blocks (256x256 plane), 512 block slots.
BLOCK_GRID = (16, 16)
BLOCK_SHAPE = (16, 16)
BLOCK_CAP = 512


def exports():
    """The export table; evaluated lazily so jax imports stay cheap."""
    f32, i32, u8 = jnp.float32, jnp.int32, jnp.uint8
    spec = jax.ShapeDtypeStruct
    return {
        "preprocess_chunks": (
            model.preprocess_chunks,
            (spec(PREPROCESS_SHAPE, u8),),
        ),
        "decode_coo": (
            functools.partial(model.decode_coo, shape=DECODE_SHAPE),
            (spec((DECODE_NNZ, len(DECODE_SHAPE)), i32), spec((DECODE_NNZ,), f32)),
        ),
        "decode_coo_raw": (
            functools.partial(model.decode_coo_raw, shape=DECODE_SHAPE),
            (spec((DECODE_NNZ, len(DECODE_SHAPE)), i32), spec((DECODE_NNZ,), f32)),
        ),
        "decode_coo_fast": (
            functools.partial(model.decode_coo_fast, shape=DECODE_SHAPE),
            (spec((DECODE_NNZ, len(DECODE_SHAPE)), i32), spec((DECODE_NNZ,), f32)),
        ),
        "decode_blocks": (
            functools.partial(model.decode_blocks, grid=BLOCK_GRID),
            (
                spec((BLOCK_CAP, 2), jnp.int32),
                spec((BLOCK_CAP,) + BLOCK_SHAPE, f32),
            ),
        ),
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name, fn, args):
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), lowered


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="export a single entry point")
    ap.add_argument(
        "--dump-stats",
        action="store_true",
        help="print HLO op histogram per module (L2 fusion sanity check)",
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, args) in exports().items():
        if ns.only and name != ns.only:
            continue
        text, _lowered = lower_entry(name, fn, args)
        path = os.path.join(ns.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
            ],
        }
        if ns.dump_stats:
            ops = {}
            for line in text.splitlines():
                line = line.strip()
                if "=" in line and line.split("=", 1)[1].strip():
                    rhs = line.split("=", 1)[1].strip()
                    op = rhs.split("(")[0].split()[-1] if "(" in rhs else ""
                    if op:
                        ops[op] = ops.get(op, 0) + 1
            top = sorted(ops.items(), key=lambda kv: -kv[1])[:8]
            print(f"[aot] {name}: {len(text)} chars, top ops: {top}")
        print(f"[aot] wrote {path} ({len(text)} chars)")

    if not ns.only:
        mpath = os.path.join(ns.out_dir, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
