//! Training data loader over FTSF — the paper's motivating dense use case
//! (§V.A): "fetching a slice of the tensor is a more common use case than
//! retrieving the whole tensor ... we can efficiently fetch only the
//! specific chunks that have a particular batch of the dataset".
//!
//! Stores an FFHQ-like image tensor, then drives a training loop's input
//! pipeline: shuffled mini-batch slice reads, optionally preprocessed by
//! the AOT-compiled XLA pipeline (u8 -> normalized f32), comparing against
//! the Binary baseline that must fetch the whole tensor for any batch.
//!
//! ```bash
//! cargo run --release --example training_loader
//! ```

use delta_tensor::prelude::*;
use delta_tensor::util::{human_bytes, Pcg64, RunStats};
use delta_tensor::workload::{ffhq_like, FfhqParams};

fn main() -> anyhow::Result<()> {
    // 256 images of 3x128x128 = ~12.6 MB: big enough that bandwidth (not
    // just request latency) matters, as in the paper's 14.6 GB regime.
    let p = FfhqParams { n: 256, channels: 3, height: 128, width: 128 };
    let batch = 8usize;
    let steps = 12usize;
    println!(
        "dataset: {:?} u8 = {} | batch {batch} | {steps} steps",
        p.shape(),
        human_bytes(p.bytes() as u64)
    );
    let dataset = ffhq_like(7, p);

    // Simulated cloud store: 1 Gbps-class bandwidth with a scaled-down
    // first-byte latency (the paper's testbed, compressed in time).
    let cost = CostModel {
        first_byte_latency: std::time::Duration::from_millis(3),
        bandwidth_bytes_per_sec: 1e9 / 8.0,
        list_latency: std::time::Duration::from_millis(1),
    };
    let store = ObjectStoreHandle::sim_mem(cost);
    let table = DeltaTable::create(store.clone(), "train")?;
    let ftsf = FtsfFormat::new(3);
    ftsf.write(&table, "dataset", &dataset.clone().into())?;
    println!(
        "stored as FTSF: {} in {} files",
        human_bytes(storage_bytes(&table, "dataset")?),
        table.snapshot()?.files.len()
    );

    // The XLA preprocess pipeline (optional: needs `make artifacts`).
    let runtime = delta_tensor::runtime::default_artifact_dir()
        .and_then(delta_tensor::runtime::Runtime::open)
        .ok();
    println!("xla preprocess: {}", if runtime.is_some() { "enabled" } else { "artifacts missing, skipping" });

    // Training loop: shuffled batch indices, slice reads, preprocess.
    let mut rng = Pcg64::new(123);
    let mut order: Vec<usize> = (0..p.n / batch).collect();
    rng.shuffle(&mut order);
    let mut fetch = RunStats::new();
    let mut prep = RunStats::new();
    store.stats().reset();
    let mut checksum = 0f64;
    for step in 0..steps {
        let b = order[step % order.len()];
        let slice = Slice::dim0(b * batch, (b + 1) * batch);
        let chunk = fetch.time(|| ftsf.read_slice(&table, "dataset", &slice)).unwrap();
        let images = chunk.to_dense()?;
        // Preprocess u8 -> normalized f32. The exported artifact takes
        // (8, 3, 64, 64) batches — exactly one mini-batch here.
        let xla_fits = runtime
            .as_ref()
            .and_then(|rt| rt.spec("preprocess_chunks").ok())
            .map(|s| s.inputs[0].0.iter().product::<usize>() == images.byte_len())
            .unwrap_or(false);
        let floats: Vec<f32> = if let (Some(rt), true) = (&runtime, xla_fits) {
            prep.time(|| rt.preprocess_chunks(images.bytes()))?
        } else {
            prep.time(|| {
                images
                    .bytes()
                    .iter()
                    .map(|&b| (b as f32 / 255.0 - 0.5) / 0.25)
                    .collect::<Vec<f32>>()
            })
        };
        checksum += floats.iter().take(16).map(|&x| x as f64).sum::<f64>();
    }
    let (gets, _, _, bytes_read, _) = store.stats().snapshot();
    println!(
        "\nFTSF loader: fetch mean {:.1} ms | preprocess mean {:.1} ms | {} GETs, {} read",
        fetch.mean() * 1e3,
        prep.mean() * 1e3,
        gets,
        human_bytes(bytes_read)
    );

    // Baseline: Binary must fetch the whole object per epoch.
    let table_b = DeltaTable::create(ObjectStoreHandle::sim_mem(cost), "b")?;
    BinaryFormat.write(&table_b, "dataset", &dataset.into())?;
    let mut baseline = RunStats::new();
    let slice = Slice::dim0(0, batch);
    for _ in 0..3 {
        baseline.time(|| BinaryFormat.read_slice(&table_b, "dataset", &slice)).unwrap();
    }
    println!(
        "Binary baseline: slice fetch mean {:.1} ms ({:.1}x slower than FTSF)",
        baseline.mean() * 1e3,
        baseline.mean() / fetch.mean()
    );
    println!("checksum {checksum:.3} (anti-DCE)");
    Ok(())
}
