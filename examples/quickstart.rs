//! Quickstart: the 60-second tour of the Delta Tensor public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Creates an in-memory lakehouse table, stores a dense tensor with FTSF
//! and a sparse tensor with BSGS, reads both back (whole and sliced),
//! shows storage sizes and the table's commit history.

use delta_tensor::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. An object store + a Delta-style table on top of it.
    //    (`ObjectStoreHandle::fs` / `sim_fs` for durable or simulated-cloud
    //    stores; `mem` keeps the demo self-contained.)
    let store = ObjectStoreHandle::mem();
    let table = DeltaTable::create(store, "quickstart")?;

    // 2. A dense tensor: 8 RGB 32x32 "images" -> FTSF, chunked per image.
    let mut image = DenseTensor::zeros(DType::U8, &[8, 3, 32, 32]);
    for i in 0..8 {
        image.set_from_f64(&[i, 0, 0, 0], (10 * i) as f64)?;
    }
    let ftsf = FtsfFormat::new(3);
    ftsf.write(&table, "images", &image.clone().into())?;
    println!(
        "stored 'images' {:?} as FTSF: {} on disk",
        image.shape(),
        delta_tensor::util::human_bytes(storage_bytes(&table, "images")?)
    );

    // 3. Read a slice: only the chunks of images 2..4 are fetched.
    let batch = ftsf.read_slice(&table, "images", &Slice::dim0(2, 4))?.to_dense()?;
    assert_eq!(batch.shape(), &[2, 3, 32, 32]);
    assert_eq!(batch.get_as_f64(&[0, 0, 0, 0])?, 20.0);
    println!("sliced images[2:4] -> {:?}", batch.shape());

    // 4. A sparse tensor -> BSGS (the paper's recommended reader-optimized
    //    sparse format).
    let sparse = SparseCoo::new(
        DType::F32,
        &[4, 100, 100],
        vec![0, 10, 10, 1, 50, 50, 3, 99, 99],
        vec![1.0, 2.0, 3.0],
    )?;
    let bsgs = BsgsFormat::default();
    bsgs.write(&table, "events", &sparse.clone().into())?;
    let day1 = bsgs.read_slice(&table, "events", &Slice::index(1))?.to_sparse()?;
    assert_eq!(day1.nnz(), 1);
    println!(
        "stored 'events' ({} nnz) as BSGS: {}; events[1] has {} nnz",
        sparse.nnz(),
        delta_tensor::util::human_bytes(storage_bytes(&table, "events")?),
        day1.nnz()
    );

    // 5. Everything is ACID: inspect the commit history / time travel.
    println!("\ncommit history:");
    for (v, op, _ts) in table.history()? {
        println!("  v{v}: {op}");
    }
    let v1 = table.snapshot_at(1)?;
    println!("time travel to v1: {} files", v1.files.len());

    // 6. Round-trip check.
    assert_eq!(ftsf.read(&table, "images")?.to_dense()?, image);
    assert_eq!(bsgs.read(&table, "events")?.to_sparse()?.to_dense()?, sparse.to_dense()?);
    println!("\nround-trips exact. done.");
    Ok(())
}
