//! END-TO-END driver: the full system on a realistic small workload,
//! proving all layers compose (recorded in EXPERIMENTS.md §E2E):
//!
//!   workload generators → coordinator worker-pool ingestion (L3, with
//!   backpressure and optimistic commits) → Delta-style table over the
//!   simulated 1 Gbps-class object store → format read paths with
//!   row-group/file pruning → AOT-compiled XLA decode (L1/L2 artifacts via
//!   PJRT) on the serving path → OPTIMIZE + VACUUM maintenance →
//!   paper-style headline metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use delta_tensor::coordinator::{Coordinator, IngestJob};
use delta_tensor::prelude::*;
use delta_tensor::util::{human_bytes, Pcg64, RunStats, Stopwatch};
use delta_tensor::workload::{self, FfhqParams, UberParams};

fn main() -> anyhow::Result<()> {
    println!("=== Delta Tensor end-to-end pipeline ===\n");

    // --- Stage 0: simulated cloud + lakehouse table -----------------------
    let cost = CostModel::fast_sim(); // structured like the paper's 1 Gbps link
    let store = ObjectStoreHandle::sim_mem(cost);
    let table = DeltaTable::create(store.clone(), "lakehouse")?;
    let coordinator = Coordinator::new(table.clone(), 4, 8);
    println!("table 'lakehouse' on simulated cloud store (4 ingest workers)\n");

    // --- Stage 1: parallel ingestion of a mixed workload ------------------
    let sw = Stopwatch::start();
    // 6 dense image shards (auto-routes to FTSF)...
    for shard in 0..6u64 {
        let images = workload::ffhq_like(
            shard,
            FfhqParams { n: 16, channels: 3, height: 64, width: 64 },
        );
        coordinator.submit(IngestJob {
            id: format!("images-{shard:02}"),
            layout: "auto".into(),
            data: images.into(),
        });
    }
    // ...plus the sparse event tensor (auto-routes to BSGS) and a CSF copy.
    let events = workload::uber_like(
        99,
        UberParams { days: 24, hours: 24, grid_x: 64, grid_y: 64, events: 30_000, hotspots: 8 },
    );
    coordinator.submit(IngestJob { id: "events".into(), layout: "auto".into(), data: events.clone().into() });
    coordinator.submit(IngestJob { id: "events-csf".into(), layout: "CSF".into(), data: events.clone().into() });
    let errors = coordinator.drain();
    anyhow::ensure!(errors.is_empty(), "ingest errors: {errors:?}");
    let snap = table.snapshot()?;
    println!(
        "ingested {} tensors in {:.2}s -> v{}, {} files, {}",
        coordinator.list_tensors()?.len(),
        sw.secs(),
        snap.version,
        snap.files.len(),
        human_bytes(snap.total_bytes())
    );
    println!(
        "layouts: images-00={}, events={}",
        delta_tensor::coordinator::discover_layout(&table, "images-00")?,
        delta_tensor::coordinator::discover_layout(&table, "events")?
    );

    // --- Stage 2: serving with pruned reads -------------------------------
    store.stats().reset();
    let plan_full = delta_tensor::query::plan(&table, "events", None)?;
    let plan_slice = delta_tensor::query::plan(&table, "events", Some(&Slice::index(11)))?;
    println!(
        "\nread plans: full={}/{} files ({}), slice day-11={}/{} files ({})",
        plan_full.selected_files,
        plan_full.total_files,
        human_bytes(plan_full.selected_bytes),
        plan_slice.selected_files,
        plan_slice.total_files,
        human_bytes(plan_slice.selected_bytes)
    );
    let mut slice_t = RunStats::new();
    let mut rng = Pcg64::new(5);
    for _ in 0..6 {
        let day = rng.below(24);
        let s = Slice::index(day);
        let got = slice_t.time(|| coordinator.read_slice("events", &s)).unwrap();
        let want = events.slice(&s)?;
        anyhow::ensure!(
            got.to_dense()? == want.to_dense()?,
            "slice mismatch on day {day}"
        );
    }
    println!("6 verified day-slices, mean {:.1} ms", slice_t.mean() * 1e3);

    // --- Stage 3: XLA decode on the serving path (L1/L2 artifacts) --------
    match delta_tensor::runtime::default_artifact_dir()
        .and_then(delta_tensor::runtime::Runtime::open)
    {
        Ok(rt) => {
            println!("\nXLA runtime: entry points {:?}", rt.entry_points());
            let s = Slice::index(7);
            let fetched = coordinator.read_slice("events", &s)?;
            let sub = fetched.to_sparse()?;
            // events day-slice is (1, 24, 64, 64); drop dim 0 to fit the
            // rank-3 (24, 64, 64) decode artifact.
            let squeezed = SparseCoo::new(
                DType::F32,
                &[24, 64, 64],
                sub.indices().chunks(4).flat_map(|c| c[1..].to_vec()).collect(),
                sub.values().to_vec(),
            )?;
            let (xla_dense, used_xla) =
                delta_tensor::query::decode_slice_xla(&rt, &squeezed.clone().into())?;
            let cpu_dense = squeezed.to_dense()?.as_f32()?;
            anyhow::ensure!(used_xla, "slice should fit the artifact");
            anyhow::ensure!(xla_dense == cpu_dense, "XLA decode must match CPU decode");
            println!("XLA decode_coo matches CPU decode on day-7 slice ✓");
            // Dense path: preprocess one FTSF chunk batch.
            let imgs = coordinator.read_slice("images-00", &Slice::dim0(0, 8))?.to_dense()?;
            let floats = rt.preprocess_chunks(imgs.bytes())?;
            println!(
                "XLA preprocess_chunks: {} u8 -> {} normalized f32 ✓",
                imgs.byte_len(),
                floats.len()
            );
        }
        Err(e) => println!("\n(XLA stage skipped: {e})"),
    }

    // --- Stage 4: maintenance (OPTIMIZE + VACUUM + time travel) -----------
    let frag = CooFormat { rows_per_file: 2048, rows_per_group: 512, ..Default::default() };
    frag.write(&table, "frag", &events.clone().into())?;
    let before = delta_tensor::formats::common_parts_count(&table, "frag", "COO")?;
    coordinator.optimize("frag")?;
    let after = delta_tensor::formats::common_parts_count(&table, "frag", "COO")?;
    let vacuumed = table.vacuum()?;
    println!(
        "\nOPTIMIZE frag: {before} -> {after} files; VACUUM removed {vacuumed} objects"
    );
    let old = table.snapshot_at(snap.version)?;
    println!("time travel to v{}: {} files still reconstructable", snap.version, old.files.len());

    // --- Stage 5: headline metrics (paper shape check) ---------------------
    let pt = BinaryFormat;
    pt.write(&table, "events-pt", &events.clone().into())?;
    let pt_size = storage_bytes(&table, "events-pt")? as f64;
    let bsgs_size = storage_bytes(&table, "events")? as f64;
    let csf_size = storage_bytes(&table, "events-csf")? as f64;
    let mut pt_slice = RunStats::new();
    for _ in 0..4 {
        pt_slice.time(|| pt.read_slice(&table, "events-pt", &Slice::index(3)).unwrap());
    }
    let mut bsgs_slice = RunStats::new();
    for _ in 0..4 {
        bsgs_slice.time(|| coordinator.read_slice("events", &Slice::index(3)).unwrap());
    }
    println!("\n=== headline metrics (paper: Cr ≤ 13.2%, BSGS slice −55% vs PT) ===");
    println!("  Cr(BSGS) = {:.2}%   Cr(CSF) = {:.2}%", bsgs_size / pt_size * 100.0, csf_size / pt_size * 100.0);
    println!(
        "  slice read: PT {:.1} ms vs BSGS {:.1} ms ({:+.1}%)",
        pt_slice.mean() * 1e3,
        bsgs_slice.mean() * 1e3,
        (bsgs_slice.mean() / pt_slice.mean() - 1.0) * 100.0
    );
    println!("\ncoordinator metrics:\n{}", coordinator.metrics().report());
    println!("e2e pipeline complete.");
    Ok(())
}
