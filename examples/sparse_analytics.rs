//! Sparse spatio-temporal analytics over the Uber-pickups-like tensor —
//! the paper's sparse scenario (§V.B) as an application: store the event
//! tensor in each sparse format, compare their footprints, then answer
//! day-level analytical queries via slice reads.
//!
//! ```bash
//! cargo run --release --example sparse_analytics
//! ```

use delta_tensor::prelude::*;
use delta_tensor::util::human_bytes;
use delta_tensor::workload::{uber_like, UberParams};

fn main() -> anyhow::Result<()> {
    let p = UberParams { days: 28, hours: 24, grid_x: 96, grid_y: 128, events: 40_000, hotspots: 8 };
    let tensor = uber_like(2024, p);
    println!(
        "events tensor {:?}: {} nnz, density {:.4}%",
        p.shape(),
        tensor.nnz(),
        tensor.density() * 100.0
    );

    let store = ObjectStoreHandle::mem();
    let table = DeltaTable::create(store, "uber")?;
    let data: TensorData = tensor.clone().into();

    // Store in every sparse format (+ pt-like baseline) and compare.
    let formats: Vec<(&str, Box<dyn TensorStore>)> = vec![
        ("pt", Box::new(BinaryFormat)),
        ("coo", Box::new(CooFormat::default())),
        ("csr", Box::new(CsrFormat::default())),
        ("csf", Box::new(CsfFormat::default())),
        ("bsgs", Box::new(BsgsFormat::with_edge(16))),
    ];
    println!("\nfootprints (same tensor, five formats):");
    let mut pt_size = 0u64;
    for (name, fmt) in &formats {
        let id = format!("events-{name}");
        fmt.write(&table, &id, &data)?;
        let size = storage_bytes(&table, &id)?;
        if *name == "pt" {
            pt_size = size;
        }
        println!(
            "  {name:<5} {:>10}   Cr = {:5.2}%",
            human_bytes(size),
            size as f64 / pt_size as f64 * 100.0
        );
    }

    // Analytics: busiest day, per-day totals, morning-vs-evening split —
    // each computed from one day slice (the paper's X[i,:,:,:] workload).
    println!("\nper-day analytics via BSGS slice reads:");
    let bsgs = BsgsFormat::with_edge(16);
    let mut busiest = (0usize, 0.0f64);
    for day in 0..p.days {
        let slice = bsgs.read_slice(&table, "events-bsgs", &Slice::index(day))?.to_sparse()?;
        let total: f64 = slice.values().iter().sum();
        if total > busiest.1 {
            busiest = (day, total);
        }
        if day < 7 {
            // morning = hours 6..12, evening = 16..22
            let morning: f64 = (0..slice.nnz())
                .filter(|&r| (6..12).contains(&slice.coord(r)[1]))
                .map(|r| slice.values()[r])
                .sum();
            let evening: f64 = (0..slice.nnz())
                .filter(|&r| (16..22).contains(&slice.coord(r)[1]))
                .map(|r| slice.values()[r])
                .sum();
            println!(
                "  day {day}: {total:6.0} pickups (morning {morning:5.0}, evening {evening:5.0})"
            );
        }
    }
    println!("  busiest day: {} with {:.0} pickups", busiest.0, busiest.1);

    // Consistency: a slice through any format agrees with the source.
    let day = busiest.0;
    let want = tensor.slice(&Slice::index(day))?.to_dense()?;
    for (name, fmt) in &formats {
        let id = format!("events-{name}");
        let got = fmt.read_slice(&table, &id, &Slice::index(day))?.to_dense()?;
        assert_eq!(got, want, "{name} slice mismatch");
    }
    println!("\nall five formats agree on day {day}. done.");
    Ok(())
}
